//! Multi-DNN scene recognition (the paper's UC3): two models — a scene
//! classifier on images and an audio-event classifier — run in parallel
//! under joint SLOs. Shows the multi-DNN decision space, the contention
//! model, and the STP/NTT/Fairness metrics of §4.1.2.
//!
//! Run: `cargo run --release --example multi_dnn_scene`

use carin::moo::{baselines, rass, Metric, Statistic};
use carin::prelude::*;

fn main() {
    let zoo = Registry::paper();
    for device in carin::device::profiles::all() {
        println!("==== {} ====", device.name);
        let p = carin::config::use_case("uc3", &zoo, &device).unwrap();
        println!(
            "decision space: {} combinations across {} tasks",
            p.space.len(),
            p.tasks.len()
        );
        let sol = rass::solve(&p);
        let d0 = &sol.designs[0];
        println!("d0: {}", d0.describe(&p));
        let m = p.metrics(&d0.config);
        println!(
            "  STP = {:.3} (max {}), NTT = {:.3}, Fairness = {:.3}",
            m.stp,
            p.tasks.len(),
            m.value(Metric::Ntt, Statistic::Avg, None),
            m.fairness
        );
        for (t, tm) in m.tasks.iter().enumerate() {
            println!(
                "  task{t}: avgL {:.2} ms (σ {:.2}), acc {:.2}, MF {:.1} MB",
                tm.latency_ms.mean, tm.latency_ms.std, tm.accuracy,
                tm.mf_bytes / 1e6
            );
        }

        // the multi-DNN-unaware baseline ignores contention: show why
        // that matters.
        match baselines::multi_dnn_unaware(&p).config {
            Some(cfg) => {
                let mu = p.metrics(&cfg);
                println!(
                    "unaware baseline: {}\n  STP = {:.3}, Fairness = {:.3} (CARIn: {:.3}/{:.3})",
                    cfg.describe(&p.registry),
                    mu.stp, mu.fairness, m.stp, m.fairness
                );
            }
            None => println!("unaware baseline: FAILED constraints under contention"),
        }
        println!();
    }
}
