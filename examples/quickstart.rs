//! Quickstart: formulate the paper's UC1 (real-time image classification)
//! for a device, solve it with RASS, and inspect the designs + switching
//! policy — the complete offline phase of CARIn in ~20 lines.
//!
//! Run: `cargo run --release --example quickstart`

use carin::prelude::*;

fn main() {
    // 1. The model repository (paper Tables 2-5) and target device (Table 6).
    let zoo = Registry::paper();
    let device = profiles::by_name("s20").unwrap();
    println!("device: {} ({}, engines {:?})", device.name, device.soc,
             device.engines.iter().map(|e| e.name()).collect::<Vec<_>>());

    // 2. Formulate the device-specific MOO problem from the use case's SLOs:
    //    max accuracy & throughput s.t. max latency <= 41.67 ms (24 FPS).
    let problem = carin::config::use_case("uc1", &zoo, &device).unwrap();
    println!(
        "decision space |X| = {} ({} objectives, {} constraints)",
        problem.space.len(),
        problem.objectives.len(),
        problem.constraints.len()
    );
    for o in &problem.objectives {
        println!("  objective:  {}", o.describe());
    }
    for c in &problem.constraints {
        println!("  constraint: {}", c.describe());
    }

    // 3. Solve once with RASS: a design set + switching policy, ready for
    //    zero-overhead runtime adaptation.
    let solution = rass::solve(&problem);
    println!(
        "\nRASS: |X'| = {} feasible, solved in {:?}",
        solution.feasible_count, solution.solve_time
    );
    for (i, d) in solution.designs.iter().enumerate() {
        println!("  d[{i}] {}", d.describe(&problem));
    }

    // 4. The Runtime Manager adapts by table lookup — no re-solving.
    let mut rm = RuntimeManager::new(solution);
    println!("\ninitial design: d[{}]", rm.current_design());
    let cpu_overload = carin::moo::rass::EnvState::calm().with_engine(Engine::Cpu);
    if let Some(d) = rm.observe(cpu_overload, 1.0) {
        println!("CPU overload   -> d[{d}]");
    }
    if let Some(d) = rm.observe(carin::moo::rass::EnvState::calm().with_memory(), 2.0) {
        println!("memory squeeze -> d[{d}]");
    }
    if let Some(d) = rm.observe(carin::moo::rass::EnvState::calm(), 3.0) {
        println!("recovered      -> d[{d}]");
    }
    println!("mean decision latency: {:.0} ns", rm.mean_decision_ns());
}
