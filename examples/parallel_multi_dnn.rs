//! Parallel multi-DNN serving demo: a UC3-style workload (scene
//! recognition + audio classification) through the per-engine worker
//! pool.
//!
//! The pinned two-engine solution routes the scene model to the CPU and
//! the audio model to the GPU; [`PooledCoordinator`] spawns one
//! engine-owning worker thread per processor, so the two models execute
//! concurrently instead of interleaving on one loop. The per-engine
//! `carin_engine_*` gauge series in the Prometheus snapshot show each
//! worker's queue depth and busy time.
//!
//! Runs on the PJRT-free stub executor: `cargo run --release --example
//! parallel_multi_dnn` (no `make artifacts` needed). Pass
//! `--telemetry <path>` to dump the merged event timeline as JSON-lines
//! to `<path>` and a Prometheus metric snapshot to `<path>.prom`.

use std::sync::mpsc;

use carin::config;
use carin::coordinator::ServeOptions;
use carin::device::Engine;
use carin::runtime::{synthetic_manifest, StubEngine};
use carin::workload;
use carin::zoo::Registry;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let telemetry_path = args
        .iter()
        .position(|a| a == "--telemetry")
        .and_then(|i| args.get(i + 1))
        .cloned();

    let reg = Registry::paper();
    let sol = config::pinned_uc3_solution(&reg);
    let engines: Vec<&str> = sol.policy.engines.iter().map(|e| e.name()).collect();
    println!(
        "uc3 pinned: {} tasks across {} engine workers ({})",
        sol.designs[0].config.assignments.len(),
        engines.len(),
        engines.join("+")
    );
    for (t, a) in sol.designs[0].config.assignments.iter().enumerate() {
        println!(
            "  task {t}: {} [{}] on {}",
            reg.models[a.variant.model].name,
            a.variant.scheme.name(),
            a.proc.engine().name()
        );
    }

    let manifest = synthetic_manifest(&reg);
    // 2 ms of simulated engine latency makes the concurrency visible:
    // 2x150 requests take ~300 ms pooled vs ~600 ms single-loop
    let factory = |_: Engine| -> anyhow::Result<StubEngine> {
        Ok(StubEngine::with_latency(2.0))
    };
    let options = ServeOptions::new()
        .telemetry_path_opt(telemetry_path.map(std::path::PathBuf::from));
    let mut coord = options.build_pooled(factory, &reg, &sol, manifest)?;

    let (tx, rx) = mpsc::channel();
    let producers =
        workload::spawn_producers(workload::for_use_case("uc3", 150), tx, 7, 0.0);
    let report = coord.serve(rx)?;
    for h in producers {
        let _ = h.join();
    }

    for t in &report.tasks {
        println!(
            "task {} [{}]: {} completed, {} retried, {} failed, {} shed, {} met deadline",
            t.task, t.artifact, t.completed, t.retried, t.failed, t.shed, t.deadline_met
        );
        println!(
            "    exec mean {:.3} ms  p95 {:.3} ms  e2e mean {:.3} ms",
            t.latency_ms.mean,
            t.latency_ms.percentile(95.0),
            t.e2e_ms.mean
        );
    }
    println!(
        "\n{} requests over a {:.2} s window: {:.1} req/s throughput, {:.1} req/s goodput",
        report.total_requests, report.window_s, report.throughput_rps, report.goodput_rps
    );

    let tel = coord.telemetry();
    if let Some(h) = tel.registry.histogram("carin_exec_latency_ms") {
        println!(
            "exec latency histogram: p50 {:.3} ms  p90 {:.3} ms  p99 {:.3} ms ({} samples)",
            h.percentile(50.0),
            h.percentile(90.0),
            h.percentile(99.0),
            h.count()
        );
    }
    println!("\nper-engine series:");
    for line in tel.prometheus().lines() {
        if line.contains("carin_engine_") && !line.starts_with('#') {
            println!("  {line}");
        }
    }
    if let Some(path) = options.dump_telemetry(tel)? {
        println!(
            "telemetry: {} events ({} dropped) -> {}, metrics -> {}.prom",
            tel.recorder.len(),
            tel.recorder.dropped(),
            path.display(),
            path.display()
        );
    }
    Ok(())
}
