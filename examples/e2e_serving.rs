//! End-to-end serving driver (the headline validation): every layer of
//! the stack composes on real compute —
//!
//!   Pallas kernels (L1) → JAX models (L2) → AOT HLO text + npz weights
//!   → rust PJRT engine → RASS-selected designs → router/batcher →
//!   batched request serving with latency/throughput reporting.
//!
//! Python is not involved at any point of this binary's execution.
//!
//! Run: `make artifacts && cargo run --release --example e2e_serving`

use std::sync::mpsc;

use carin::coordinator::ServeOptions;
use carin::moo::rass;
use carin::prelude::*;
use carin::runtime::load_manifest;
use carin::workload;

fn main() -> anyhow::Result<()> {
    let zoo = Registry::paper();
    let manifest = load_manifest(std::path::Path::new("artifacts"))?;
    println!("manifest: {} artifacts", manifest.len());

    for uc in ["uc1", "uc3", "uc4"] {
        let device = profiles::by_name("s20").unwrap();
        let p = carin::config::use_case(uc, &zoo, &device).unwrap();
        let sol = rass::solve(&p);
        println!("\n==== {} on {} ====", uc, device.name);
        println!("d0 = {}", sol.designs[0].describe(&p));

        let mut coord = ServeOptions::new().build_single(&zoo, &sol, manifest.clone())?;
        println!(
            "engine: PJRT CPU, {} design-set models preloaded (vs {} in the full zoo)",
            coord.loaded_models(),
            manifest.len()
        );

        let n = 120;
        let (tx, rx) = mpsc::channel();
        let producers =
            workload::spawn_producers(workload::for_use_case(uc, n), tx, 7, 0.005);
        let report = coord.serve(rx)?;
        for h in producers {
            let _ = h.join();
        }
        for t in &report.tasks {
            println!(
                "task {} [{:18}] {:4} reqs  exec mean {:7.3} ms  p95 {:7.3} ms  e2e mean {:7.3} ms  ({} retried, {} failed, {} shed)",
                t.task,
                t.artifact,
                t.completed,
                t.latency_ms.mean,
                t.latency_ms.percentile(95.0),
                t.e2e_ms.mean,
                t.retried,
                t.failed,
                t.shed,
            );
        }
        println!(
            "=> {} requests in {:.2} s = {:.1} req/s ({:.1} req/s goodput)",
            report.total_requests, report.wall_s, report.throughput_rps, report.goodput_rps
        );
    }
    Ok(())
}
