//! Runtime adaptation (the paper's §7.2, Figures 7 & 8): replay the
//! Figure-7 event script — CPU overload, then a RAM squeeze, then
//! recovery — against UC1 on the Galaxy S20, with the Runtime Manager
//! switching designs by policy lookup.
//!
//! Run: `cargo run --release --example runtime_adaptation`

use carin::coordinator::run_trace;
use carin::manager::EventSchedule;
use carin::moo::rass;
use carin::prelude::*;

fn main() {
    let zoo = Registry::paper();
    let device = profiles::by_name("s20").unwrap();
    let p = carin::config::use_case("uc1", &zoo, &device).unwrap();
    let sol = rass::solve(&p);
    println!("{}", carin::harness::tables::table7_8_designs(&p, &sol));

    let schedule = EventSchedule::figure7(p.device.ram_bytes());
    let log = run_trace(&p, sol, schedule, 30.0, 1.0 / 24.0, 11);

    println!(
        "{} inference rounds, {} design switches, mean decision {:.0} ns\n",
        log.points.len(),
        log.switches,
        log.mean_decision_ns
    );
    println!("  time   design  latency    thr/s   acc     mem");
    let mut mark = 0.0;
    for pt in &log.points {
        if pt.switched_to.is_none() && pt.events.is_empty() && pt.t_s < mark {
            continue;
        }
        mark = pt.t_s + 2.0;
        println!(
            "  {:5.1}s  d[{}]   {:7.2}ms {:7.1} {:6.2} {:6.1}MB {}{}",
            pt.t_s,
            pt.design,
            pt.latency_ms[0],
            pt.throughput,
            pt.accuracy[0],
            pt.mem_mb,
            if pt.events.is_empty() { String::new() } else { format!(" !! {}", pt.events.join("; ")) },
            match pt.switched_to {
                Some(d) => format!(" -> d[{d}]"),
                None => String::new(),
            }
        );
    }

    // Accuracy preservation takeaway (§7.2.1): the design set keeps
    // accuracy within a tight band across all switches.
    let accs: Vec<f64> = log.points.iter().map(|p| p.accuracy[0]).collect();
    let min = accs.iter().copied().fold(f64::MAX, f64::min);
    let max = accs.iter().copied().fold(f64::MIN, f64::max);
    println!("\naccuracy band across adaptation: [{min:.2}, {max:.2}]");
}
