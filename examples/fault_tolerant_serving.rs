//! Fault-tolerant serving demo: the UC1 stack under injected faults.
//!
//! A seeded [`FaultInjector`] wraps the executor with 10% transient
//! inference errors, occasional latency spikes, and a hard outage window
//! on the calm design's route. Supervised execution retries transients
//! with capped exponential backoff; the outage trips the fault signal,
//! the Runtime Manager falls back to a design off the faulted engine,
//! health probes detect the outage's end and the policy recovers — all
//! without a single process-level error.
//!
//! Runs on the PJRT-free stub executor: `cargo run --release --example
//! fault_tolerant_serving` (no `make artifacts` needed). Pass
//! `--telemetry <path>` to dump the event timeline as JSON-lines to
//! `<path>` and a Prometheus metric snapshot to `<path>.prom`.

use std::sync::mpsc;

use carin::config;
use carin::coordinator::ServeOptions;
use carin::device::profiles;
use carin::moo::rass::{self, EnvState};
use carin::runtime::{synthetic_manifest, FaultInjector, FaultSpec, StubEngine};
use carin::workload;
use carin::zoo::Registry;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let telemetry_path = args
        .iter()
        .position(|a| a == "--telemetry")
        .and_then(|i| args.get(i + 1))
        .cloned();

    let reg = Registry::paper();
    let dev = profiles::by_name("s20").unwrap();
    let p = config::use_case("uc1", &reg, &dev).unwrap();
    let sol = rass::solve(&p);
    println!("uc1 on {}: {} designs in the switching policy", dev.name, sol.designs.len());
    println!("d0 = {}", sol.designs[0].describe(&p));

    let manifest = synthetic_manifest(&reg);
    let mut inj = FaultInjector::new(StubEngine::with_latency(0.2), 1234);
    inj.set_default(FaultSpec::transient(0.10).with_spikes(0.05, 2.0));
    let d0 = sol.policy.design_for(EnvState::calm());
    let a = &sol.designs[d0].config.assignments[0];
    let stem = format!("{}_{}", reg.models[a.variant.model].artifact, a.variant.scheme.name());
    println!("injecting: 10% transients everywhere, outage on {stem} (calls 40..=60)\n");
    inj.set_for(&stem, FaultSpec::transient(0.10).with_outage(40, 60));

    let options = ServeOptions::new()
        .telemetry_path_opt(telemetry_path.map(std::path::PathBuf::from));
    let mut coord = options.build_with_engine(inj, &reg, &sol, manifest)?;
    let (tx, rx) = mpsc::channel();
    let producers =
        workload::spawn_producers(workload::for_use_case("uc1", 300), tx, 7, 0.0);
    let report = coord.serve(rx)?;
    for h in producers {
        let _ = h.join();
    }

    for t in &report.tasks {
        println!(
            "task {} [{}]: {} completed, {} retried, {} failed, {} shed, {} met deadline",
            t.task, t.artifact, t.completed, t.retried, t.failed, t.shed, t.deadline_met
        );
        println!(
            "    exec mean {:.3} ms  p95 {:.3} ms  e2e mean {:.3} ms",
            t.latency_ms.mean,
            t.latency_ms.percentile(95.0),
            t.e2e_ms.mean
        );
    }
    println!(
        "\n{} requests over a {:.2} s window: {:.1} req/s throughput, {:.1} req/s goodput",
        report.total_requests, report.window_s, report.throughput_rps, report.goodput_rps
    );
    println!(
        "switches: {} fallback, {} recovery (final design index {})",
        report.fallback_switches,
        report.recovered_switches,
        coord.current_design()
    );
    let stats = &coord.engine().stats;
    println!(
        "injector: {} calls, {} injected errors, {} spikes, {} failed loads",
        stats.calls, stats.injected_errors, stats.injected_spikes, stats.failed_loads
    );
    for (i, s) in coord.runtime_manager().switches.iter().enumerate() {
        println!(
            "  switch {}: d{} -> d{} at {:.2}s (state: troubled={:#06b} faulted={:#06b} mem={})",
            i, s.from, s.to, s.sim_time_s, s.state.troubled, s.state.faulted, s.state.memory
        );
    }

    let tel = coord.telemetry();
    if let Some(h) = tel.registry.histogram("carin_e2e_latency_ms") {
        println!(
            "e2e latency histogram: p50 {:.3} ms  p90 {:.3} ms  p99 {:.3} ms ({} samples)",
            h.percentile(50.0),
            h.percentile(90.0),
            h.percentile(99.0),
            h.count()
        );
    }
    if let Some(path) = options.dump_telemetry(tel)? {
        println!(
            "telemetry: {} events ({} dropped) -> {}, metrics -> {}.prom",
            tel.recorder.len(),
            tel.recorder.dropped(),
            path.display(),
            path.display()
        );
    }
    Ok(())
}
