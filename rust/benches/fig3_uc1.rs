//! Figure 3 reproduction: UC1 (real-time image classification) optimality
//! of CARIn vs B-A / B-S / transferred / OODIn per device and processor
//! state, plus the §7.1.2 takeaway ratios and solve-cost timings.

use carin::bench::Bencher;
use carin::harness::figures;
use carin::moo::rass;
use carin::zoo::Registry;

fn main() {
    let reg = Registry::paper();
    println!("=== Figure 3: UC1 optimality per device/state ===");
    let rows = figures::figure_single("uc1", &reg);
    println!("{}", figures::render(&rows));
    for m in ["B-A", "B-S", "OODIn"] {
        if let Some((avg, max)) = figures::gain_over(&rows, m) {
            println!("CARIn gain over {m}: avg {avg:.2}x, max {max:.2}x");
        }
    }
    // transferred baselines aggregated
    let mut t_ratios = Vec::new();
    for m in ["T_Pixel 7", "T_Galaxy S20 FE", "T_Galaxy A71"] {
        if let Some((avg, max)) = figures::gain_over(&rows, m) {
            t_ratios.push((avg, max));
        }
    }
    if !t_ratios.is_empty() {
        let avg = t_ratios.iter().map(|r| r.0).sum::<f64>() / t_ratios.len() as f64;
        let max = t_ratios.iter().map(|r| r.1).fold(f64::MIN, f64::max);
        println!("CARIn gain over transferred: avg {avg:.2}x, max {max:.2}x");
    }

    println!("\n=== solve cost (per device) ===");
    let b = Bencher::quick();
    for dev in carin::device::profiles::all() {
        let p = carin::config::use_case("uc1", &reg, &dev).unwrap();
        b.run(&format!("rass_solve/uc1/{}", dev.name), || rass::solve(&p));
    }
}
