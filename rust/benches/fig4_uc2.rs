//! Figure 4 reproduction: UC2 (text classification under a 90 MB memory
//! cap) optimality of CARIn vs the baselines per device and state.

use carin::bench::Bencher;
use carin::harness::figures;
use carin::moo::rass;
use carin::zoo::Registry;

fn main() {
    let reg = Registry::paper();
    println!("=== Figure 4: UC2 optimality per device/state ===");
    let rows = figures::figure_single("uc2", &reg);
    println!("{}", figures::render(&rows));
    for m in ["B-A", "B-S", "OODIn"] {
        if let Some((avg, max)) = figures::gain_over(&rows, m) {
            println!("CARIn gain over {m}: avg {avg:.2}x, max {max:.2}x");
        }
    }

    let b = Bencher::quick();
    for dev in carin::device::profiles::all() {
        let p = carin::config::use_case("uc2", &reg, &dev).unwrap();
        b.run(&format!("rass_solve/uc2/{}", dev.name), || rass::solve(&p));
    }
}
