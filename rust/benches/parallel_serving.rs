//! Parallel serving benchmark: single-loop coordinator vs the
//! per-engine worker pool on a two-task UC3-style workload whose tasks
//! are pinned to two distinct engines.
//!
//! Runs on the PJRT-free [`StubEngine`] with a synthetic manifest (no
//! `make artifacts` needed); the stub burns a fixed per-call latency so
//! engine-level parallelism is the only thing separating the two
//! coordinators. With both arrival queues flooded, the single loop
//! executes 2xN requests serially (~2N * exec_ms wall) while the pool
//! overlaps the two engines (~N * exec_ms wall), so goodput should
//! roughly double.
//!
//! Writes the comparison to `BENCH_serving.json` in the working
//! directory (CI uploads it as an artifact and gates on the speedup).

use std::collections::BTreeMap;
use std::sync::mpsc;

use carin::config;
use carin::coordinator::serve::ServeReport;
use carin::coordinator::ServeOptions;
use carin::device::Engine;
use carin::runtime::{synthetic_manifest, StubEngine};
use carin::util::json::Json;
use carin::workload;
use carin::zoo::Registry;

const N_PER_TASK: usize = 150;
const EXEC_MS: f64 = 2.0;

struct RunResult {
    report: ServeReport,
    exec_p50_ms: f64,
    exec_p99_ms: f64,
}

fn percentiles(tel: &carin::telemetry::Telemetry) -> (f64, f64) {
    match tel.registry.histogram("carin_exec_latency_ms") {
        Some(h) => (h.percentile(50.0), h.percentile(99.0)),
        None => (0.0, 0.0),
    }
}

fn run_single(reg: &Registry, sol: &carin::moo::Solution) -> anyhow::Result<RunResult> {
    let manifest = synthetic_manifest(reg);
    let engine = StubEngine::with_latency(EXEC_MS);
    let mut coord = ServeOptions::new().build_with_engine(engine, reg, sol, manifest)?;
    let (tx, rx) = mpsc::channel();
    let producers =
        workload::spawn_producers(workload::for_use_case("uc3", N_PER_TASK), tx, 23, 0.0);
    let report = coord.serve(rx)?;
    for h in producers {
        let _ = h.join();
    }
    let (exec_p50_ms, exec_p99_ms) = percentiles(coord.telemetry());
    Ok(RunResult { report, exec_p50_ms, exec_p99_ms })
}

fn run_pooled(reg: &Registry, sol: &carin::moo::Solution) -> anyhow::Result<RunResult> {
    let manifest = synthetic_manifest(reg);
    let factory =
        |_: Engine| -> anyhow::Result<StubEngine> { Ok(StubEngine::with_latency(EXEC_MS)) };
    let mut coord = ServeOptions::new().build_pooled(factory, reg, sol, manifest)?;
    let (tx, rx) = mpsc::channel();
    let producers =
        workload::spawn_producers(workload::for_use_case("uc3", N_PER_TASK), tx, 23, 0.0);
    let report = coord.serve(rx)?;
    for h in producers {
        let _ = h.join();
    }
    let (exec_p50_ms, exec_p99_ms) = percentiles(coord.telemetry());
    Ok(RunResult { report, exec_p50_ms, exec_p99_ms })
}

fn print_row(label: &str, r: &RunResult) {
    println!(
        "{:12} {:>9.1} {:>9.1} {:>6} {:>6} {:>6} {:>9.2} {:>9.2} {:>8.3}",
        label,
        r.report.goodput_rps,
        r.report.throughput_rps,
        r.report.total_requests,
        r.report.failed,
        r.report.shed,
        r.exec_p50_ms,
        r.exec_p99_ms,
        r.report.window_s
    );
}

fn side(r: &RunResult) -> Json {
    let mut o = BTreeMap::new();
    o.insert("goodput_rps".into(), Json::Num(r.report.goodput_rps));
    o.insert("throughput_rps".into(), Json::Num(r.report.throughput_rps));
    o.insert("completed".into(), Json::Num(r.report.total_requests as f64));
    o.insert("failed".into(), Json::Num(r.report.failed as f64));
    o.insert("shed".into(), Json::Num(r.report.shed as f64));
    o.insert("p50_ms".into(), Json::Num(r.exec_p50_ms));
    o.insert("p99_ms".into(), Json::Num(r.exec_p99_ms));
    o.insert("window_s".into(), Json::Num(r.report.window_s));
    Json::Obj(o)
}

fn main() -> anyhow::Result<()> {
    let reg = Registry::paper();
    // the pinned solution routes scene->CPU and audio->GPU, so the pool
    // has two genuinely independent engine queues to overlap
    let sol = config::pinned_uc3_solution(&reg);

    println!(
        "=== uc3 pinned 2-engine serving, {} requests/task, stub exec {} ms ===",
        N_PER_TASK, EXEC_MS
    );
    println!(
        "{:12} {:>9} {:>9} {:>6} {:>6} {:>6} {:>9} {:>9} {:>8}",
        "coordinator", "goodput", "rps", "done", "fail", "shed", "p50 ms", "p99 ms", "window"
    );

    let single = run_single(&reg, &sol)?;
    print_row("single-loop", &single);
    let pooled = run_pooled(&reg, &sol)?;
    print_row("pooled", &pooled);

    let speedup = pooled.report.goodput_rps / single.report.goodput_rps.max(1e-9);
    println!(
        "\npooled goodput speedup over single loop: {speedup:.2}x ({:.1} -> {:.1} req/s)",
        single.report.goodput_rps, pooled.report.goodput_rps
    );

    let mut o = BTreeMap::new();
    o.insert("bench".into(), Json::Str("parallel_serving".into()));
    o.insert("workload".into(), Json::Str("uc3-pinned-2-engine".into()));
    o.insert("n_requests_per_task".into(), Json::Num(N_PER_TASK as f64));
    o.insert("exec_ms".into(), Json::Num(EXEC_MS));
    o.insert("single".into(), side(&single));
    o.insert("pooled".into(), side(&pooled));
    o.insert("speedup_goodput".into(), Json::Num(speedup));
    std::fs::write("BENCH_serving.json", Json::Obj(o).dump())?;
    println!("comparison -> BENCH_serving.json");
    Ok(())
}
