//! Parallel serving benchmark: single-loop coordinator vs the
//! per-engine worker pool on a two-task UC3-style workload whose tasks
//! are pinned to two distinct engines.
//!
//! Runs on the PJRT-free [`StubEngine`] with a synthetic manifest (no
//! `make artifacts` needed); the stub burns a fixed per-call latency so
//! engine-level parallelism is the only thing separating the two
//! coordinators. With both arrival queues flooded, the single loop
//! executes 2xN requests serially (~2N * exec_ms wall) while the pool
//! overlaps the two engines (~N * exec_ms wall), so goodput should
//! roughly double.
//!
//! Writes the comparison to `BENCH_serving.json` in the working
//! directory (CI uploads it as an artifact and gates on the speedup).

use std::alloc::{GlobalAlloc, Layout, System};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::time::Instant;

use carin::config;
use carin::coordinator::serve::{ServeReport, ServeRequest};
use carin::coordinator::ServeOptions;
use carin::device::Engine;
use carin::runtime::{synthetic_manifest, StubEngine};
use carin::util::json::Json;
use carin::workload;
use carin::zoo::Registry;

const N_PER_TASK: usize = 150;
const EXEC_MS: f64 = 2.0;
/// Requests per task for the memory-path A/B runs (instant stub calls,
/// pre-loaded queues: framework overhead is all that is measured).
const MEM_N: usize = 300;
const SCHEMA_VERSION: f64 = 2.0;

/// Counts heap allocation calls so the bench can report
/// `allocs_per_request` on the serving hot path.
struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOC_CALLS.load(Ordering::Relaxed)
}

struct RunResult {
    report: ServeReport,
    exec_p50_ms: f64,
    exec_p99_ms: f64,
}

fn percentiles(tel: &carin::telemetry::Telemetry) -> (f64, f64) {
    match tel.registry.histogram("carin_exec_latency_ms") {
        Some(h) => (h.percentile(50.0), h.percentile(99.0)),
        None => (0.0, 0.0),
    }
}

fn run_single(reg: &Registry, sol: &carin::moo::Solution) -> anyhow::Result<RunResult> {
    let manifest = synthetic_manifest(reg);
    let engine = StubEngine::with_latency(EXEC_MS);
    let mut coord = ServeOptions::new().build_with_engine(engine, reg, sol, manifest)?;
    let (tx, rx) = mpsc::channel();
    let producers =
        workload::spawn_producers(workload::for_use_case("uc3", N_PER_TASK), tx, 23, 0.0);
    let report = coord.serve(rx)?;
    for h in producers {
        let _ = h.join();
    }
    let (exec_p50_ms, exec_p99_ms) = percentiles(coord.telemetry());
    Ok(RunResult { report, exec_p50_ms, exec_p99_ms })
}

fn run_pooled(reg: &Registry, sol: &carin::moo::Solution) -> anyhow::Result<RunResult> {
    let manifest = synthetic_manifest(reg);
    let factory =
        |_: Engine| -> anyhow::Result<StubEngine> { Ok(StubEngine::with_latency(EXEC_MS)) };
    let mut coord = ServeOptions::new().build_pooled(factory, reg, sol, manifest)?;
    let (tx, rx) = mpsc::channel();
    let producers =
        workload::spawn_producers(workload::for_use_case("uc3", N_PER_TASK), tx, 23, 0.0);
    let report = coord.serve(rx)?;
    for h in producers {
        let _ = h.join();
    }
    let (exec_p50_ms, exec_p99_ms) = percentiles(coord.telemetry());
    Ok(RunResult { report, exec_p50_ms, exec_p99_ms })
}

fn print_row(label: &str, r: &RunResult) {
    println!(
        "{:12} {:>9.1} {:>9.1} {:>6} {:>6} {:>6} {:>9.2} {:>9.2} {:>8.3}",
        label,
        r.report.goodput_rps,
        r.report.throughput_rps,
        r.report.total_requests,
        r.report.failed,
        r.report.shed,
        r.exec_p50_ms,
        r.exec_p99_ms,
        r.report.window_s
    );
}

/// `per_task` requests per uc3 task, all enqueued up front with the
/// sender already closed: the serve loop drains flat out and the
/// channel-node allocations stay outside any measured window.
fn preloaded(per_task: usize) -> mpsc::Receiver<ServeRequest> {
    let (tx, rx) = mpsc::channel();
    let now = Instant::now();
    for task in 0..2 {
        for i in 0..per_task {
            let _ = tx.send(ServeRequest {
                task,
                id: (task as u64) << 48 | i as u64,
                submitted: now,
                deadline: None,
            });
        }
    }
    rx
}

struct MemoryPath {
    copy_p50_ms: f64,
    copy_p99_ms: f64,
    zero_copy_p50_ms: f64,
    zero_copy_p99_ms: f64,
    pool_hit_rate: f64,
    allocs_per_request: f64,
}

/// A/B the copying baseline (`pool_slots(0)`) against the pooled
/// zero-copy path on instant stub calls, and measure steady-state
/// allocations per request differentially (a small run vs a 4x run on
/// the warm coordinator — per-run setup cancels out).
fn run_memory_path(reg: &Registry, sol: &carin::moo::Solution) -> anyhow::Result<MemoryPath> {
    let manifest = synthetic_manifest(reg);

    let mut copy = ServeOptions::new()
        .pool_slots(0)
        .expected_requests(4 * MEM_N)
        .build_with_engine(StubEngine::new(), reg, sol, manifest.clone())?;
    copy.serve(preloaded(MEM_N))?; // warmup
    copy.serve(preloaded(4 * MEM_N))?;
    let (copy_p50_ms, copy_p99_ms) = percentiles(copy.telemetry());

    let mut zc = ServeOptions::new()
        .expected_requests(4 * MEM_N)
        .build_with_engine(StubEngine::new(), reg, sol, manifest)?;
    zc.serve(preloaded(MEM_N))?; // warmup
    let a0 = allocs();
    zc.serve(preloaded(MEM_N))?;
    let small = allocs() - a0;
    let a0 = allocs();
    zc.serve(preloaded(4 * MEM_N))?;
    let large = allocs() - a0;
    let (zero_copy_p50_ms, zero_copy_p99_ms) = percentiles(zc.telemetry());

    let extra_requests = (3 * MEM_N * 2) as f64;
    Ok(MemoryPath {
        copy_p50_ms,
        copy_p99_ms,
        zero_copy_p50_ms,
        zero_copy_p99_ms,
        pool_hit_rate: zc.buffer_pool_stats().hit_rate(),
        allocs_per_request: large.saturating_sub(small) as f64 / extra_requests,
    })
}

fn side(r: &RunResult) -> Json {
    let mut o = BTreeMap::new();
    o.insert("goodput_rps".into(), Json::Num(r.report.goodput_rps));
    o.insert("throughput_rps".into(), Json::Num(r.report.throughput_rps));
    o.insert("completed".into(), Json::Num(r.report.total_requests as f64));
    o.insert("failed".into(), Json::Num(r.report.failed as f64));
    o.insert("shed".into(), Json::Num(r.report.shed as f64));
    o.insert("p50_ms".into(), Json::Num(r.exec_p50_ms));
    o.insert("p99_ms".into(), Json::Num(r.exec_p99_ms));
    o.insert("window_s".into(), Json::Num(r.report.window_s));
    Json::Obj(o)
}

fn main() -> anyhow::Result<()> {
    let reg = Registry::paper();
    // the pinned solution routes scene->CPU and audio->GPU, so the pool
    // has two genuinely independent engine queues to overlap
    let sol = config::pinned_uc3_solution(&reg);

    println!(
        "=== uc3 pinned 2-engine serving, {} requests/task, stub exec {} ms ===",
        N_PER_TASK, EXEC_MS
    );
    println!(
        "{:12} {:>9} {:>9} {:>6} {:>6} {:>6} {:>9} {:>9} {:>8}",
        "coordinator", "goodput", "rps", "done", "fail", "shed", "p50 ms", "p99 ms", "window"
    );

    let single = run_single(&reg, &sol)?;
    print_row("single-loop", &single);
    let pooled = run_pooled(&reg, &sol)?;
    print_row("pooled", &pooled);

    let speedup = pooled.report.goodput_rps / single.report.goodput_rps.max(1e-9);
    println!(
        "\npooled goodput speedup over single loop: {speedup:.2}x ({:.1} -> {:.1} req/s)",
        single.report.goodput_rps, pooled.report.goodput_rps
    );

    let mem = run_memory_path(&reg, &sol)?;
    println!(
        "memory path: copy p50 {:.4} ms, zero-copy p50 {:.4} ms, pool hit rate {:.3}, \
         {:.4} allocs/request",
        mem.copy_p50_ms, mem.zero_copy_p50_ms, mem.pool_hit_rate, mem.allocs_per_request
    );

    let mut o = BTreeMap::new();
    o.insert("bench".into(), Json::Str("parallel_serving".into()));
    o.insert("schema_version".into(), Json::Num(SCHEMA_VERSION));
    o.insert("workload".into(), Json::Str("uc3-pinned-2-engine".into()));
    o.insert("n_requests_per_task".into(), Json::Num(N_PER_TASK as f64));
    o.insert("exec_ms".into(), Json::Num(EXEC_MS));
    o.insert("single".into(), side(&single));
    o.insert("pooled".into(), side(&pooled));
    o.insert("speedup_goodput".into(), Json::Num(speedup));
    o.insert("allocs_per_request".into(), Json::Num(mem.allocs_per_request));
    let side_obj = |p50: f64, p99: f64| {
        let mut m = BTreeMap::new();
        m.insert("p50_ms".to_string(), Json::Num(p50));
        m.insert("p99_ms".to_string(), Json::Num(p99));
        Json::Obj(m)
    };
    let mut mp = BTreeMap::new();
    mp.insert("copy".into(), side_obj(mem.copy_p50_ms, mem.copy_p99_ms));
    mp.insert("zero_copy".into(), side_obj(mem.zero_copy_p50_ms, mem.zero_copy_p99_ms));
    mp.insert("pool_hit_rate".into(), Json::Num(mem.pool_hit_rate));
    o.insert("memory_path".into(), Json::Obj(mp));
    std::fs::write("BENCH_serving.json", Json::Obj(o).dump())?;
    println!("comparison -> BENCH_serving.json");
    Ok(())
}
