//! Figures 7/8 reproduction: runtime-adaptation traces for UC1/S20 and
//! UC3/A71 under the paper's event scripts, plus micro-benchmarks of the
//! adaptation hot path (monitor sample, policy lookup, RM observe).

use carin::bench::Bencher;
use carin::config;
use carin::coordinator::run_trace;
use carin::device::{profiles, Simulator};
use carin::manager::{EventSchedule, Monitor, RuntimeManager};
use carin::moo::rass::{self, EnvState};
use carin::zoo::Registry;

fn trace_summary(uc: &str, dev_name: &str, sched_of: impl Fn(f64) -> EventSchedule) {
    let reg = Registry::paper();
    let dev = profiles::by_name(dev_name).unwrap();
    let p = config::use_case(uc, &reg, &dev).unwrap();
    let sol = rass::solve(&p);
    println!("--- {} on {} ---", uc, dev.name);
    for (i, d) in sol.designs.iter().enumerate() {
        println!("  d[{i}] {}", d.describe(&p));
    }
    let log = run_trace(&p, sol, sched_of(p.device.ram_bytes()), 32.0, 1.0 / 24.0, 11);
    println!(
        "  {} rounds, {} switches, mean decision {:.0} ns",
        log.points.len(),
        log.switches,
        log.mean_decision_ns
    );
    // per-design residency + latency/accuracy bands (the figure's y-axes)
    let mut designs: Vec<usize> = log.points.iter().map(|pt| pt.design).collect();
    designs.sort_unstable();
    designs.dedup();
    for d in designs {
        let pts: Vec<_> = log.points.iter().filter(|pt| pt.design == d).collect();
        let lat: f64 =
            pts.iter().map(|pt| pt.latency_ms[0]).sum::<f64>() / pts.len() as f64;
        let mem = pts.iter().map(|pt| pt.mem_mb).fold(f64::MIN, f64::max);
        println!(
            "  d[{d}]: {:4} rounds, avg lat {:7.2} ms, acc {:.2}, peak mem {:6.1} MB",
            pts.len(),
            lat,
            pts[0].accuracy[0],
            mem
        );
    }
}

fn main() {
    println!("=== Figure 7: UC1 on Galaxy S20 FE ===");
    trace_summary("uc1", "s20", EventSchedule::figure7);
    println!("\n=== Figure 8: UC3 on Galaxy A71 ===");
    trace_summary("uc3", "a71", EventSchedule::figure8);

    println!("\n=== adaptation hot-path microbenchmarks ===");
    let reg = Registry::paper();
    let dev = profiles::galaxy_s20();
    let p = config::use_case("uc1", &reg, &dev).unwrap();
    let sol = rass::solve(&p);
    let b = Bencher::default();

    let policy = sol.policy.clone();
    let states: Vec<EnvState> = policy.iter_states().map(|(s, _)| s).collect();
    let mut i = 0;
    b.run("policy_lookup", || {
        i = (i + 1) % states.len();
        policy.design_for(states[i])
    });

    let mut sim = Simulator::new(dev.clone(), 3);
    let mut monitor = Monitor::new(dev.engines.clone(), 2);
    b.run("monitor_sample", || monitor.sample(&sim));

    let mut rm = RuntimeManager::new(sol);
    let mut flip = false;
    b.run("rm_observe_with_state_change", || {
        flip = !flip;
        let s = if flip {
            EnvState::calm().with_engine(carin::device::Engine::Cpu)
        } else {
            EnvState::calm()
        };
        rm.observe(s, 0.0)
    });

    b.run("simulator_inference_step", || {
        sim.run_inference(
            &reg,
            carin::zoo::Variant {
                model: reg.find("EfficientNet Lite0").unwrap(),
                scheme: carin::zoo::Scheme::Ffx8,
            },
            carin::device::Proc::Npu,
            0,
        )
    });
}
