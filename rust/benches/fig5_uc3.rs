//! Figure 5 reproduction: UC3 (parallel scene + audio classification)
//! optimality of CARIn vs multi-DNN-unaware / transferred / OODIn per
//! device and processor combination.

use carin::bench::Bencher;
use carin::harness::figures;
use carin::moo::rass;
use carin::zoo::Registry;

fn main() {
    let reg = Registry::paper();
    println!("=== Figure 5: UC3 optimality per device/processor combination ===");
    let rows = figures::figure_multi("uc3", &reg, None);
    println!("{}", figures::render(&rows));
    for m in ["unaware", "OODIn"] {
        if let Some((avg, max)) = figures::gain_over(&rows, m) {
            println!("CARIn gain over {m}: avg {avg:.2}x, max {max:.2}x");
        }
    }
    let mut t_ratios = Vec::new();
    for m in ["T_Pixel 7", "T_Galaxy S20 FE", "T_Galaxy A71"] {
        if let Some((avg, max)) = figures::gain_over(&rows, m) {
            t_ratios.push((avg, max));
        }
    }
    if !t_ratios.is_empty() {
        let avg = t_ratios.iter().map(|r| r.0).sum::<f64>() / t_ratios.len() as f64;
        let max = t_ratios.iter().map(|r| r.1).fold(f64::MIN, f64::max);
        println!("CARIn gain over transferred: avg {avg:.2}x, max {max:.2}x");
    }

    let b = Bencher::quick();
    for dev in carin::device::profiles::all() {
        let p = carin::config::use_case("uc3", &reg, &dev).unwrap();
        b.run(&format!("rass_solve/uc3/{}", dev.name), || rass::solve(&p));
    }
}
