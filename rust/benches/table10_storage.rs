//! Table 10 reproduction: model storage requirements of CARIn (only the
//! RASS design set) vs OODIn (the full candidate zoo), per use case and
//! device.

use carin::harness::tables;
use carin::zoo::Registry;

fn main() {
    println!("=== Table 10: storage requirements (MB) ===");
    let reg = Registry::paper();
    println!(
        "{:>4} | {:>14} | {:>9} | {:>9} | {:>9}",
        "uc", "device", "CARIn", "OODIn", "reduction"
    );
    let rows = tables::table10_storage(&reg);
    for r in &rows {
        println!(
            "{:>4} | {:>14} | {:>9.2} | {:>9.2} | {:>8.2}x",
            r.use_case, r.device, r.carin_mb, r.oodin_mb, r.reduction
        );
    }
    let avg = rows.iter().map(|r| r.reduction).sum::<f64>() / rows.len() as f64;
    let max = rows.iter().map(|r| r.reduction).fold(f64::MIN, f64::max);
    println!("\naverage reduction {avg:.2}x, max {max:.2}x (paper: up to 19.98x)");
}
