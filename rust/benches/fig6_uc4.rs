//! Figure 6 reproduction: UC4 (three facial-attribute models, batch 4,
//! 10 ms latency cap) — top-5 processor combinations per device,
//! CARIn vs the baselines. Most baselines fail UC4's tight constraint,
//! as in the paper.

use carin::bench::Bencher;
use carin::harness::figures;
use carin::moo::rass;
use carin::zoo::Registry;

fn main() {
    let reg = Registry::paper();
    println!("=== Figure 6: UC4 optimality, top-5 combinations per device ===");
    let rows = figures::figure_multi("uc4", &reg, Some(5));
    println!("{}", figures::render(&rows));
    let failures = rows.iter().filter(|r| r.optimality.is_none()).count();
    println!(
        "baseline failures (patterned bars in the paper): {} of {} rows",
        failures,
        rows.len()
    );
    for m in ["unaware", "OODIn"] {
        if let Some((avg, max)) = figures::gain_over(&rows, m) {
            println!("CARIn gain over {m}: avg {avg:.2}x, max {max:.2}x");
        }
    }

    let b = Bencher::quick();
    for dev in carin::device::profiles::all() {
        let p = carin::config::use_case("uc4", &reg, &dev).unwrap();
        b.run(&format!("rass_solve/uc4/{}", dev.name), || rass::solve(&p));
    }
}
