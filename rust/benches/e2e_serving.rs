//! End-to-end PJRT serving benchmark (headline metric): real inference
//! latency and throughput of the design-set artifacts on the CPU PJRT
//! client — load/compile cost, per-variant steady-state latency across
//! quantisation schemes, and batched serving throughput.
//!
//! Skips gracefully when `make artifacts` has not been run.

use std::sync::mpsc;

use carin::config;
use carin::coordinator::ServeOptions;
use carin::device::profiles;
use carin::moo::rass;
use carin::runtime::engine::{zero_input, InferenceEngine};
use carin::runtime::load_manifest;
use carin::util::Summary;
use carin::workload;
use carin::zoo::Registry;

fn main() -> anyhow::Result<()> {
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("artifacts not built; run `make artifacts` first");
        return Ok(());
    }
    let manifest = load_manifest(dir)?;
    let mut engine = InferenceEngine::cpu()?;

    println!("=== per-variant steady-state latency (PJRT CPU, 5 warmup + 50 runs) ===");
    println!(
        "{:28} {:>10} {:>10} {:>10} {:>12}",
        "artifact", "mean ms", "p95 ms", "min ms", "load ms"
    );
    // cover the scheme spectrum on two model families
    for stem in [
        "cnn_s_fp32", "cnn_s_fp16", "cnn_s_dr8", "cnn_s_fx8", "cnn_s_ffx8",
        "cnn_l_fp32", "cnn_l_ffx8",
        "bert_s_fp32", "bert_s_ffx8",
        "face_gender_ffx8", "yamnet_lite_fp32", "scene_m_fx8",
    ] {
        let Some(meta) = manifest.iter().find(|m| m.stem == stem) else { continue };
        engine.load(meta)?;
        let load_ms = engine.loaded().iter().find(|m| m.meta.stem == stem).unwrap().load_time_ms;
        let lat = engine.measure(stem, &zero_input(meta), 5, 50)?;
        let s = Summary::of(&lat);
        println!(
            "{:28} {:>10.3} {:>10.3} {:>10.3} {:>12.1}",
            stem,
            s.mean,
            s.percentile(95.0),
            s.min,
            load_ms
        );
    }

    println!("\n=== batched serving throughput (design set per use case) ===");
    let reg = Registry::paper();
    for uc in ["uc1", "uc3", "uc4"] {
        let dev = profiles::by_name("s20").unwrap();
        let p = config::use_case(uc, &reg, &dev).unwrap();
        let sol = rass::solve(&p);
        let mut coord = ServeOptions::new().build_single(&reg, &sol, manifest.clone())?;
        let (tx, rx) = mpsc::channel();
        let producers =
            workload::spawn_producers(workload::for_use_case(uc, 160), tx, 9, 0.0);
        let report = coord.serve(rx)?;
        for h in producers {
            let _ = h.join();
        }
        println!(
            "{:4}: {:4} reqs in {:6.2} s = {:7.1} req/s  (models resident: {})",
            uc,
            report.total_requests,
            report.wall_s,
            report.throughput_rps,
            coord.loaded_models()
        );
        for t in &report.tasks {
            println!(
                "      task {} [{:18}] exec mean {:7.3} ms  p95 {:7.3} ms",
                t.task,
                t.artifact,
                t.latency_ms.mean,
                t.latency_ms.percentile(95.0)
            );
        }
    }
    Ok(())
}
