//! Table 9 reproduction: OODIn's weighted-sum re-solve time versus the
//! decision-space dimension (500 / 2000 / 5000 / 10000), and the RASS
//! policy lookup that replaces it at runtime. The paper's point: the
//! re-solve sits on the critical path of every runtime event and grows
//! with |X|, while CARIn's lookup is constant and ~instant.

use carin::harness::tables;

fn main() {
    println!("=== Table 9: solving time vs decision-space dimension ===");
    let rows = tables::table9_solve_time(&[500, 2000, 5000, 10000], 50, 4);
    println!(
        "{:>7} | {:>13} | {:>13} | {:>16}",
        "|X|", "OODIn avg ms", "OODIn max ms", "RASS lookup ns"
    );
    for r in &rows {
        println!(
            "{:>7} | {:>13.3} | {:>13.3} | {:>16.1}",
            r.dimension, r.oodin_avg_ms, r.oodin_max_ms, r.rass_lookup_avg_ns
        );
    }
    let worst = rows.iter().map(|r| r.oodin_max_ms).fold(f64::MIN, f64::max);
    let lookup_ms = rows.iter().map(|r| r.rass_lookup_avg_ns).sum::<f64>()
        / rows.len() as f64
        / 1e6;
    println!(
        "\nadaptation overhead: OODIn up to {worst:.2} ms per event; CARIn {lookup_ms:.6} ms \
         ({}x smaller)",
        (worst / lookup_ms) as u64
    );
}
