//! Chaos serving benchmark: goodput (successful-within-deadline req/s)
//! of the UC1 serving stack with and without fault injection.
//!
//! Runs on the PJRT-free [`StubEngine`] with a synthetic manifest so it
//! needs no `make artifacts`; the stub burns a fixed per-call latency to
//! make retries and backoff measurable in the goodput numbers.
//!
//! Besides the goodput table, the chaos run prints its supervision
//! timeline (fault → fallback switch → probes → recovery switch) from
//! the telemetry recorder and writes the full event stream as JSON-lines
//! to the temp dir for replay.

use std::sync::mpsc;

use carin::config;
use carin::coordinator::ServingCoordinator;
use carin::coordinator::serve::ServeReport;
use carin::device::profiles;
use carin::moo::rass::{self, EnvState};
use carin::runtime::{synthetic_manifest, FaultInjector, FaultSpec, StubEngine};
use carin::telemetry::{Event, EventKind};
use carin::workload;
use carin::zoo::Registry;

const N_REQUESTS: usize = 400;
const EXEC_MS: f64 = 0.2;

/// What the bench keeps from a run's [`carin::telemetry::Telemetry`]
/// after the coordinator is dropped.
struct TelemetrySnapshot {
    events: Vec<Event>,
    dropped: u64,
    window_s: f64,
    jsonl: String,
    e2e_p50_ms: f64,
    e2e_p99_ms: f64,
}

fn run(
    reg: &Registry,
    sol: &carin::moo::Solution,
    spec: Option<FaultSpec>,
) -> anyhow::Result<(ServeReport, u64, TelemetrySnapshot)> {
    let manifest = synthetic_manifest(reg);
    let mut inj = FaultInjector::new(StubEngine::with_latency(EXEC_MS), 42);
    if let Some(spec) = spec.clone() {
        inj.set_default(spec);
    }
    if let Some(spec) = spec {
        // hard outage on the calm design's route forces a fallback
        let d0 = sol.policy.design_for(EnvState::calm());
        let a = &sol.designs[d0].config.assignments[0];
        let stem = format!("{}_{}", reg.models[a.variant.model].artifact, a.variant.scheme.name());
        inj.set_for(&stem, spec.with_outage(60, 80));
    }
    let mut coord = ServingCoordinator::with_engine(inj, reg, sol, manifest)?;
    let (tx, rx) = mpsc::channel();
    let producers =
        workload::spawn_producers(workload::for_use_case("uc1", N_REQUESTS), tx, 17, 0.0);
    let report = coord.serve(rx)?;
    for h in producers {
        let _ = h.join();
    }
    let tel = coord.telemetry();
    let e2e = tel.registry.histogram("carin_e2e_latency_ms");
    let snap = TelemetrySnapshot {
        events: tel.recorder.events(),
        dropped: tel.recorder.dropped(),
        window_s: tel.window_s().unwrap_or(0.0),
        jsonl: tel.events_jsonl(),
        e2e_p50_ms: e2e.map_or(0.0, |h| h.percentile(50.0)),
        e2e_p99_ms: e2e.map_or(0.0, |h| h.percentile(99.0)),
    };
    Ok((report, coord.engine().stats.injected_errors, snap))
}

/// Print the supervision-loop timeline (fault/switch/heal events; probes
/// are summarised by count) from a run's retained events.
fn print_timeline(snap: &TelemetrySnapshot) {
    let mut probes = 0u64;
    let mut probe_ok = 0u64;
    for e in &snap.events {
        let t_s = e.t_ns as f64 / 1e9;
        match e.kind {
            EventKind::FaultRaised { engine, task } => {
                println!("  {t_s:8.3}s fault raised on engine {engine} (task {task})");
            }
            EventKind::FaultCleared { engine } => {
                println!("  {t_s:8.3}s fault cleared on engine {engine} ({probe_ok}/{probes} probes ok so far)");
            }
            EventKind::Probe { ok, .. } => {
                probes += 1;
                if ok {
                    probe_ok += 1;
                }
            }
            EventKind::Switch { from, to, bad_mask, decision_ns, fallback, .. } => {
                let why = if fallback { "fallback" } else { "recovery" };
                println!(
                    "  {t_s:8.3}s {why} switch d{from} -> d{to} (bad_mask={bad_mask:#06b}, decided in {decision_ns} ns)"
                );
            }
            _ => {}
        }
    }
}

fn print_row(label: &str, r: &ServeReport, injected: u64) {
    println!(
        "{:22} {:>9.1} {:>9.1} {:>6} {:>6} {:>6} {:>6} {:>5}/{:<5} {:>9}",
        label,
        r.goodput_rps,
        r.throughput_rps,
        r.total_requests,
        r.retried,
        r.failed,
        r.shed,
        r.fallback_switches,
        r.recovered_switches,
        injected
    );
}

fn main() -> anyhow::Result<()> {
    let reg = Registry::paper();
    let dev = profiles::by_name("s20").unwrap();
    let p = config::use_case("uc1", &reg, &dev).unwrap();
    let sol = rass::solve(&p);

    println!(
        "=== uc1/s20 chaos serving, {} requests, stub exec {} ms ===",
        N_REQUESTS, EXEC_MS
    );
    println!(
        "{:22} {:>9} {:>9} {:>6} {:>6} {:>6} {:>6} {:>11} {:>9}",
        "condition", "goodput", "rps", "done", "retry", "fail", "shed", "fall/recov", "injected"
    );

    let (clean, injected, _clean_tel) = run(&reg, &sol, None)?;
    print_row("clean", &clean, injected);

    let (chaos, injected, chaos_tel) =
        run(&reg, &sol, Some(FaultSpec::transient(0.10).with_spikes(0.05, 2.0)))?;
    print_row("10% transient+outage", &chaos, injected);

    let retained = 100.0 * chaos.goodput_rps / clean.goodput_rps.max(1e-9);
    println!(
        "\ngoodput retained under injection: {:.1}% ({:.1} -> {:.1} req/s)",
        retained, clean.goodput_rps, chaos.goodput_rps
    );

    println!(
        "\nchaos telemetry: {} events retained ({} dropped), {:.2}s window, e2e p50 {:.3} ms / p99 {:.3} ms",
        chaos_tel.events.len(),
        chaos_tel.dropped,
        chaos_tel.window_s,
        chaos_tel.e2e_p50_ms,
        chaos_tel.e2e_p99_ms
    );
    println!("supervision timeline:");
    print_timeline(&chaos_tel);

    let path = std::env::temp_dir().join("chaos_serving.events.jsonl");
    std::fs::write(&path, &chaos_tel.jsonl)?;
    println!("replayable event stream -> {}", path.display());
    Ok(())
}
