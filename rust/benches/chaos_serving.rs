//! Chaos serving benchmark: goodput (successful-within-deadline req/s)
//! of the UC1 serving stack with and without fault injection.
//!
//! Runs on the PJRT-free [`StubEngine`] with a synthetic manifest so it
//! needs no `make artifacts`; the stub burns a fixed per-call latency to
//! make retries and backoff measurable in the goodput numbers.

use std::sync::mpsc;

use carin::config;
use carin::coordinator::ServingCoordinator;
use carin::coordinator::serve::ServeReport;
use carin::device::profiles;
use carin::moo::rass::{self, EnvState};
use carin::runtime::{synthetic_manifest, FaultInjector, FaultSpec, StubEngine};
use carin::workload;
use carin::zoo::Registry;

const N_REQUESTS: usize = 400;
const EXEC_MS: f64 = 0.2;

fn run(reg: &Registry, sol: &carin::moo::Solution, spec: Option<FaultSpec>) -> anyhow::Result<(ServeReport, u64)> {
    let manifest = synthetic_manifest(reg);
    let mut inj = FaultInjector::new(StubEngine::with_latency(EXEC_MS), 42);
    if let Some(spec) = spec.clone() {
        inj.set_default(spec);
    }
    if let Some(spec) = spec {
        // hard outage on the calm design's route forces a fallback
        let d0 = sol.policy.design_for(EnvState::calm());
        let a = &sol.designs[d0].config.assignments[0];
        let stem = format!("{}_{}", reg.models[a.variant.model].artifact, a.variant.scheme.name());
        inj.set_for(&stem, spec.with_outage(60, 80));
    }
    let mut coord = ServingCoordinator::with_engine(inj, reg, sol, manifest)?;
    let (tx, rx) = mpsc::channel();
    let producers =
        workload::spawn_producers(workload::for_use_case("uc1", N_REQUESTS), tx, 17, 0.0);
    let report = coord.serve(rx)?;
    for h in producers {
        let _ = h.join();
    }
    Ok((report, coord.engine().stats.injected_errors))
}

fn print_row(label: &str, r: &ServeReport, injected: u64) {
    println!(
        "{:22} {:>9.1} {:>9.1} {:>6} {:>6} {:>6} {:>6} {:>5}/{:<5} {:>9}",
        label,
        r.goodput_rps,
        r.throughput_rps,
        r.total_requests,
        r.retried,
        r.failed,
        r.shed,
        r.fallback_switches,
        r.recovered_switches,
        injected
    );
}

fn main() -> anyhow::Result<()> {
    let reg = Registry::paper();
    let dev = profiles::by_name("s20").unwrap();
    let p = config::use_case("uc1", &reg, &dev).unwrap();
    let sol = rass::solve(&p);

    println!(
        "=== uc1/s20 chaos serving, {} requests, stub exec {} ms ===",
        N_REQUESTS, EXEC_MS
    );
    println!(
        "{:22} {:>9} {:>9} {:>6} {:>6} {:>6} {:>6} {:>11} {:>9}",
        "condition", "goodput", "rps", "done", "retry", "fail", "shed", "fall/recov", "injected"
    );

    let (clean, injected) = run(&reg, &sol, None)?;
    print_row("clean", &clean, injected);

    let (chaos, injected) =
        run(&reg, &sol, Some(FaultSpec::transient(0.10).with_spikes(0.05, 2.0)))?;
    print_row("10% transient+outage", &chaos, injected);

    let retained = 100.0 * chaos.goodput_rps / clean.goodput_rps.max(1e-9);
    println!(
        "\ngoodput retained under injection: {:.1}% ({:.1} -> {:.1} req/s)",
        retained, clean.goodput_rps, chaos.goodput_rps
    );
    Ok(())
}
