//! Chaos serving benchmark: goodput (successful-within-deadline req/s)
//! of the UC1 serving stack with and without fault injection.
//!
//! Runs on the PJRT-free [`StubEngine`] with a synthetic manifest so it
//! needs no `make artifacts`; the stub burns a fixed per-call latency to
//! make retries and backoff measurable in the goodput numbers.
//!
//! Besides the goodput table, the chaos run prints its supervision
//! timeline (fault → fallback switch → probes → recovery switch) from
//! the telemetry recorder and writes the full event stream as JSON-lines
//! to the temp dir for replay.
//!
//! A second section measures *hang* recovery: the same stack behind a
//! [`Watchdog`] with ~1% of calls stalling far past their deadline. The
//! goodput retained relative to a clean watchdog-supervised run is
//! merged into `BENCH_serving.json` under `hang_recovery` (CI gates on
//! the ratio).

use std::collections::BTreeMap;
use std::sync::mpsc;
use std::time::Duration;

use carin::config;
use carin::coordinator::serve::ServeReport;
use carin::coordinator::{FaultPolicy, ServeOptions};
use carin::device::profiles;
use carin::moo::rass::{self, EnvState};
use carin::runtime::{synthetic_manifest, FaultInjector, FaultSpec, StubEngine, Watchdog};
use carin::telemetry::{Event, EventKind};
use carin::util::json::Json;
use carin::workload;
use carin::zoo::Registry;

const N_REQUESTS: usize = 400;
const EXEC_MS: f64 = 0.2;
/// Per-call stall probability for the hang-recovery section.
const HANG_P: f64 = 0.01;
/// Requests in the hang-recovery section (more than the flooded section
/// so ~1% stalls yield a stable handful of watchdog timeouts).
const N_HANG: usize = 600;
/// Arrival pacing for the hang-recovery section: 5% of real time keeps
/// ~2 ms between arrivals, so a recovered 20 ms stall is absorbed by
/// queue slack instead of stretching the serving window — the figure
/// then measures recovery, not the stalls themselves.
const HANG_TIME_SCALE: f64 = 0.05;

/// What the bench keeps from a run's [`carin::telemetry::Telemetry`]
/// after the coordinator is dropped.
struct TelemetrySnapshot {
    events: Vec<Event>,
    dropped: u64,
    window_s: f64,
    jsonl: String,
    e2e_p50_ms: f64,
    e2e_p99_ms: f64,
}

fn run(
    reg: &Registry,
    sol: &carin::moo::Solution,
    spec: Option<FaultSpec>,
) -> anyhow::Result<(ServeReport, u64, TelemetrySnapshot)> {
    let manifest = synthetic_manifest(reg);
    let mut inj = FaultInjector::new(StubEngine::with_latency(EXEC_MS), 42);
    if let Some(spec) = spec.clone() {
        inj.set_default(spec);
    }
    if let Some(spec) = spec {
        // hard outage on the calm design's route forces a fallback
        let d0 = sol.policy.design_for(EnvState::calm());
        let a = &sol.designs[d0].config.assignments[0];
        let stem = format!("{}_{}", reg.models[a.variant.model].artifact, a.variant.scheme.name());
        inj.set_for(&stem, spec.with_outage(60, 80));
    }
    let mut coord = ServeOptions::new().build_with_engine(inj, reg, sol, manifest)?;
    let (tx, rx) = mpsc::channel();
    let producers =
        workload::spawn_producers(workload::for_use_case("uc1", N_REQUESTS), tx, 17, 0.0);
    let report = coord.serve(rx)?;
    for h in producers {
        let _ = h.join();
    }
    let tel = coord.telemetry();
    let e2e = tel.registry.histogram("carin_e2e_latency_ms");
    let snap = TelemetrySnapshot {
        events: tel.recorder.events(),
        dropped: tel.recorder.dropped(),
        window_s: tel.window_s().unwrap_or(0.0),
        jsonl: tel.events_jsonl(),
        e2e_p50_ms: e2e.map_or(0.0, |h| h.percentile(50.0)),
        e2e_p99_ms: e2e.map_or(0.0, |h| h.percentile(99.0)),
    };
    Ok((report, coord.engine().stats.injected_errors, snap))
}

/// One watchdog-supervised run: every call goes through a [`Watchdog`]
/// with a 20 ms per-call deadline (SLO 10 ms x mult 2, floored at
/// 20 ms). With `hang_p > 0` the injected stalls sleep far past that
/// deadline, so only abandon-and-respawn supervision keeps the run
/// moving. Returns the report plus the watchdog's timeout/respawn
/// counters.
fn run_supervised(
    reg: &Registry,
    sol: &carin::moo::Solution,
    hang_p: f64,
) -> anyhow::Result<(ServeReport, u64, u64)> {
    let manifest = synthetic_manifest(reg);
    let engine = Watchdog::new(move || {
        let mut inj = FaultInjector::new(StubEngine::with_latency(EXEC_MS), 42);
        if hang_p > 0.0 {
            inj.set_default(FaultSpec::transient(0.0).with_hangs(hang_p, 5_000.0));
        }
        Ok(inj)
    })?;
    let policy = FaultPolicy {
        timeout_mult: 2.0,
        timeout_floor: Duration::from_millis(20),
        ..FaultPolicy::default()
    };
    let mut coord = ServeOptions::new()
        .fault_policy(policy)
        .latency_slo_ms(10.0)
        .build_with_engine(engine, reg, sol, manifest)?;
    let (tx, rx) = mpsc::channel();
    let producers = workload::spawn_producers(
        workload::for_use_case("uc1", N_HANG),
        tx,
        17,
        HANG_TIME_SCALE,
    );
    let report = coord.serve(rx)?;
    for h in producers {
        let _ = h.join();
    }
    let stats = coord.engine().stats;
    Ok((report, stats.timeouts, stats.respawns))
}

/// Print the supervision-loop timeline (fault/switch/heal events; probes
/// are summarised by count) from a run's retained events.
fn print_timeline(snap: &TelemetrySnapshot) {
    let mut probes = 0u64;
    let mut probe_ok = 0u64;
    for e in &snap.events {
        let t_s = e.t_ns as f64 / 1e9;
        match e.kind {
            EventKind::FaultRaised { engine, task } => {
                println!("  {t_s:8.3}s fault raised on engine {engine} (task {task})");
            }
            EventKind::FaultCleared { engine } => {
                println!("  {t_s:8.3}s fault cleared on engine {engine} ({probe_ok}/{probes} probes ok so far)");
            }
            EventKind::Probe { ok, .. } => {
                probes += 1;
                if ok {
                    probe_ok += 1;
                }
            }
            EventKind::Switch { from, to, bad_mask, decision_ns, fallback, .. } => {
                let why = if fallback { "fallback" } else { "recovery" };
                println!(
                    "  {t_s:8.3}s {why} switch d{from} -> d{to} (bad_mask={bad_mask:#06b}, decided in {decision_ns} ns)"
                );
            }
            _ => {}
        }
    }
}

fn print_row(label: &str, r: &ServeReport, injected: u64) {
    println!(
        "{:22} {:>9.1} {:>9.1} {:>6} {:>6} {:>6} {:>6} {:>5}/{:<5} {:>9}",
        label,
        r.goodput_rps,
        r.throughput_rps,
        r.total_requests,
        r.retried,
        r.failed,
        r.shed,
        r.fallback_switches,
        r.recovered_switches,
        injected
    );
}

fn main() -> anyhow::Result<()> {
    let reg = Registry::paper();
    let dev = profiles::by_name("s20").unwrap();
    let p = config::use_case("uc1", &reg, &dev).unwrap();
    let sol = rass::solve(&p);

    println!(
        "=== uc1/s20 chaos serving, {} requests, stub exec {} ms ===",
        N_REQUESTS, EXEC_MS
    );
    println!(
        "{:22} {:>9} {:>9} {:>6} {:>6} {:>6} {:>6} {:>11} {:>9}",
        "condition", "goodput", "rps", "done", "retry", "fail", "shed", "fall/recov", "injected"
    );

    let (clean, injected, _clean_tel) = run(&reg, &sol, None)?;
    print_row("clean", &clean, injected);

    let (chaos, injected, chaos_tel) =
        run(&reg, &sol, Some(FaultSpec::transient(0.10).with_spikes(0.05, 2.0)))?;
    print_row("10% transient+outage", &chaos, injected);

    let retained = 100.0 * chaos.goodput_rps / clean.goodput_rps.max(1e-9);
    println!(
        "\ngoodput retained under injection: {:.1}% ({:.1} -> {:.1} req/s)",
        retained, clean.goodput_rps, chaos.goodput_rps
    );

    println!(
        "\nchaos telemetry: {} events retained ({} dropped), {:.2}s window, e2e p50 {:.3} ms / p99 {:.3} ms",
        chaos_tel.events.len(),
        chaos_tel.dropped,
        chaos_tel.window_s,
        chaos_tel.e2e_p50_ms,
        chaos_tel.e2e_p99_ms
    );
    println!("supervision timeline:");
    print_timeline(&chaos_tel);

    let path = std::env::temp_dir().join("chaos_serving.events.jsonl");
    std::fs::write(&path, &chaos_tel.jsonl)?;
    println!("replayable event stream -> {}", path.display());

    // --- hang recovery: stalls that never error, survivable only via
    // watchdog abandon-and-respawn ---
    println!(
        "\n=== hang recovery ({N_HANG} paced reqs, watchdog 20 ms deadline, {:.0}% of calls stall 5 s) ===",
        100.0 * HANG_P
    );
    println!(
        "{:22} {:>9} {:>9} {:>6} {:>6} {:>6} {:>6} {:>9} {:>9}",
        "condition", "goodput", "rps", "done", "retry", "t/o", "shed", "timeouts", "respawns"
    );
    let (wd_clean, to0, rs0) = run_supervised(&reg, &sol, 0.0)?;
    let (wd_hang, to1, rs1) = run_supervised(&reg, &sol, HANG_P)?;
    for (label, r, to, rs) in
        [("watchdog clean", &wd_clean, to0, rs0), ("watchdog 1% hangs", &wd_hang, to1, rs1)]
    {
        println!(
            "{:22} {:>9.1} {:>9.1} {:>6} {:>6} {:>6} {:>6} {:>9} {:>9}",
            label,
            r.goodput_rps,
            r.throughput_rps,
            r.total_requests,
            r.retried,
            r.timed_out,
            r.shed,
            to,
            rs
        );
    }
    let ratio = wd_hang.goodput_rps / wd_clean.goodput_rps.max(1e-9);
    println!(
        "\ngoodput retained under hangs: {:.1}% ({:.1} -> {:.1} req/s, {} retried after a timeout)",
        100.0 * ratio,
        wd_clean.goodput_rps,
        wd_hang.goodput_rps,
        wd_hang.retried_timeout
    );

    // merge next to the parallel bench's figures so CI gates one file
    let hr = {
        let mut o = BTreeMap::new();
        o.insert("clean_goodput_rps".into(), Json::Num(wd_clean.goodput_rps));
        o.insert("hang_goodput_rps".into(), Json::Num(wd_hang.goodput_rps));
        o.insert("goodput_ratio".into(), Json::Num(ratio));
        o.insert("hang_p".into(), Json::Num(HANG_P));
        o.insert("deadline_ms".into(), Json::Num(20.0));
        o.insert("watchdog_timeouts".into(), Json::Num(to1 as f64));
        o.insert("watchdog_respawns".into(), Json::Num(rs1 as f64));
        o.insert("retried_timeout".into(), Json::Num(wd_hang.retried_timeout as f64));
        o.insert("timed_out".into(), Json::Num(wd_hang.timed_out as f64));
        Json::Obj(o)
    };
    let mut root = match std::fs::read_to_string("BENCH_serving.json") {
        Ok(s) => match Json::parse(&s) {
            Ok(Json::Obj(m)) => m,
            _ => BTreeMap::new(),
        },
        Err(_) => BTreeMap::new(),
    };
    root.insert("hang_recovery".into(), hr);
    std::fs::write("BENCH_serving.json", Json::Obj(root).dump())?;
    println!("hang-recovery figures merged -> BENCH_serving.json");
    Ok(())
}
