//! Ablation study (DESIGN.md §8 design choices): RASS vs the alternatives
//! it replaces —
//!
//! * **NSGA-II** (the conventional evolutionary MOO solver §4.3 argues
//!   against re-running at runtime): front quality vs solve cost;
//! * **OODIn weighted sum**: single-solution quality + per-event re-solve;
//! * **predictor-backed profiling** (§8): solve quality when only 30% of
//!   the space is profiled and the rest is predicted.

use std::time::Instant;

use carin::config;
use carin::device::profiles;
use carin::moo::{baselines, nsga2, rass, Problem};
use carin::profiler::predictor;
use carin::zoo::Registry;

fn main() {
    let reg = Registry::paper();
    println!("=== solver ablation (UC1/UC3 x devices) ===");
    println!(
        "{:24} {:>12} {:>12} {:>14} {:>10}",
        "problem", "RASS ms", "NSGA-II ms", "OODIn ms", "d0 on GA front?"
    );
    for (uc, devname) in [("uc1", "s20"), ("uc1", "a71"), ("uc3", "a71"), ("uc2", "p7")] {
        let dev = profiles::by_name(devname).unwrap();
        let p = config::use_case(uc, &reg, &dev).unwrap();

        let t0 = Instant::now();
        let sol = rass::solve(&p);
        let rass_ms = t0.elapsed().as_secs_f64() * 1000.0;

        let t0 = Instant::now();
        let front = nsga2::solve(
            &p,
            &nsga2::Nsga2Params { population: 48, generations: 25, ..Default::default() },
        );
        let ga_ms = t0.elapsed().as_secs_f64() * 1000.0;

        let t0 = Instant::now();
        let _ = baselines::oodin(&p);
        let oodin_ms = t0.elapsed().as_secs_f64() * 1000.0;

        // is d0 undominated w.r.t. the GA front?
        let higher: Vec<bool> =
            p.objectives.iter().map(|o| o.metric.higher_is_better()).collect();
        let v0 = p.objective_vector(&sol.designs[0].config);
        let dominated = front
            .iter()
            .map(|c| p.objective_vector(c))
            .filter(|v| carin::moo::pareto::dominates(v, &v0, &higher))
            .count();
        println!(
            "{:24} {:>12.2} {:>12.2} {:>14.3} {:>10}",
            format!("{uc}/{}", dev.name),
            rass_ms,
            ga_ms,
            oodin_ms,
            if dominated == 0 { "yes" } else { "near" }
        );
    }

    println!("\n=== profiling-cost ablation: full vs 30%-profiled + predictor ===");
    println!(
        "{:24} {:>10} {:>10} {:>14} {:>14}",
        "problem", "full |pts|", "profiled", "full d0 opt", "pred d0 true-opt"
    );
    for (uc, devname) in [("uc1", "s20"), ("uc2", "a71")] {
        let dev = profiles::by_name(devname).unwrap();
        let full = config::use_case(uc, &reg, &dev).unwrap();
        let full_sol = rass::solve(&full);
        let (cache, n_train) = predictor::predicted_cache(&reg, &dev, &full.space, 0.3, 11);
        let total = cache.len();
        let approx = Problem {
            name: format!("{uc}-pred"),
            tasks: full.tasks.clone(),
            device: full.device.clone(),
            registry: full.registry.clone(),
            objectives: full.objectives.clone(),
            constraints: full.constraints.clone(),
            space: full.space.clone(),
            cache,
        };
        let approx_sol = rass::solve(&approx);
        let true_opt =
            baselines::optimality_of(&full, &approx_sol.designs[0].config);
        println!(
            "{:24} {:>10} {:>10} {:>14.3} {:>14.3}",
            format!("{uc}/{}", dev.name),
            total,
            n_train,
            full_sol.designs[0].optimality,
            true_opt
        );
    }
}
