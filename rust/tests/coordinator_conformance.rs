//! Trait-level conformance: both serving front-ends — the single-engine
//! [`carin::coordinator::ServingCoordinator`] and the per-engine
//! [`carin::coordinator::PooledCoordinator`] — are driven through the
//! object-safe [`Coordinator`] trait with identical options and an
//! identical seeded workload, and must both uphold the report contract:
//!
//! * conservation — `completed + failed + timed_out + shed` covers every
//!   submitted request exactly once;
//! * `goodput_rps <= throughput_rps` (deadline-met completions are a
//!   subset of completions);
//! * the telemetry registry agrees with the report on the terminal
//!   taxonomy.

use std::sync::mpsc;

use carin::config;
use carin::coordinator::{Coordinator, FaultPolicy, ServeOptions, ServeReport};
use carin::device::Engine;
use carin::runtime::{synthetic_manifest, StubEngine};
use carin::workload;
use carin::zoo::Registry;

const N_PER_TASK: usize = 40;
const SEED: u64 = 77;

/// Drive one coordinator — whichever concrete type hides behind the
/// trait object — through the shared seeded UC3 workload.
fn drive(coord: &mut dyn Coordinator) -> ServeReport {
    coord.set_latency_slo(50.0);
    coord.set_fault_policy(FaultPolicy::default());
    let (tx, rx) = mpsc::channel();
    let producers =
        workload::spawn_producers(workload::for_use_case("uc3", N_PER_TASK), tx, SEED, 0.0);
    let report = coord.serve(rx).expect("serve through the trait object");
    for h in producers {
        h.join().unwrap();
    }
    report
}

/// The contract every implementation must uphold, checked through the
/// same trait object that produced the report.
fn check_contract(name: &str, coord: &mut dyn Coordinator, report: &ServeReport) {
    let submitted = 2 * N_PER_TASK;
    assert_eq!(
        report.total_requests + report.failed + report.timed_out + report.shed,
        submitted,
        "{name}: request taxonomy does not cover the workload"
    );
    assert!(
        report.goodput_rps <= report.throughput_rps + 1e-9,
        "{name}: goodput {} exceeds throughput {}",
        report.goodput_rps,
        report.throughput_rps
    );
    assert_eq!(coord.current_design(), 0, "{name}: clean run left the calm design");
    let m = &coord.telemetry().registry;
    assert_eq!(m.counter("carin_requests_admitted_total"), submitted as u64);
    assert_eq!(m.counter("carin_requests_completed_total"), report.total_requests as u64);
    assert_eq!(m.counter("carin_requests_failed_total"), report.failed as u64);
    assert_eq!(m.counter("carin_requests_timed_out_total"), report.timed_out as u64);
    assert_eq!(m.counter("carin_requests_shed_total"), report.shed as u64);
}

#[test]
fn both_coordinators_uphold_the_report_contract_behind_the_trait() {
    let reg = Registry::paper();
    let sol = config::pinned_uc3_solution(&reg);
    let options = ServeOptions::new();

    let mut single = options
        .build_with_engine(StubEngine::new(), &reg, &sol, synthetic_manifest(&reg))
        .expect("single preload");
    let factory = |_: Engine| -> anyhow::Result<StubEngine> { Ok(StubEngine::new()) };
    let mut pooled = options
        .build_pooled(factory, &reg, &sol, synthetic_manifest(&reg))
        .expect("pooled preload");

    let impls: [(&str, &mut dyn Coordinator); 2] =
        [("single", &mut single), ("pooled", &mut pooled)];
    for (name, coord) in impls {
        let report = drive(&mut *coord);
        check_contract(name, &mut *coord, &report);
        // a flooded clean stub run completes everything it admits
        assert_eq!(report.failed, 0, "{name}: stub engine cannot fail");
        assert_eq!(report.timed_out, 0, "{name}: nothing should time out cleanly");
        assert_eq!(
            report.total_requests + report.shed,
            2 * N_PER_TASK,
            "{name}: completions plus sheds must cover the workload"
        );
    }
}
