//! Cross-module integration tests: use-case formulation → profiling →
//! RASS → Runtime Manager → trace, plus baseline comparisons — the
//! paper's full offline+online pipeline, per device.

use carin::config;
use carin::coordinator::run_trace;
use carin::device::{profiles, Engine};
use carin::manager::{Event, EventSchedule};
use carin::moo::{baselines, rass};
use carin::zoo::Registry;

#[test]
fn every_use_case_solves_on_every_device() {
    let reg = Registry::paper();
    for dev in profiles::all() {
        for uc in config::USE_CASES {
            let p = config::use_case(uc, &reg, &dev).unwrap();
            let sol = rass::solve(&p);
            assert!(!sol.designs.is_empty(), "{uc}/{}", dev.name);
            assert!(sol.designs.len() <= 5);
            // every design satisfies the problem constraints
            for d in &sol.designs {
                assert!(p.feasible(&d.config), "{uc}/{}: {}", dev.name, d.describe(&p));
            }
            // d0 holds the best optimality
            let d0 = &sol.designs[sol.policy.design_for(carin::moo::rass::EnvState::calm())];
            assert!(d0.roles.contains(&"d0"));
        }
    }
}

#[test]
fn uc1_s20_reproduces_table7_structure() {
    // Table 7's structure: d0 = an int8 EfficientNet-class model on CPU;
    // GPU design is FP16; the memory design is a compact int8 model.
    let reg = Registry::paper();
    let p = config::use_case("uc1", &reg, &profiles::galaxy_s20()).unwrap();
    let sol = rass::solve(&p);
    let d0 = &sol.designs[0];
    assert!(d0.config.assignments[0].variant.scheme.is_integer(),
            "d0 should be an int8 variant, got {}", d0.describe(&p));
    assert_eq!(d0.config.engine_set(), vec![Engine::Cpu]);
    // some design uses the GPU with a float scheme (the CP migration path)
    let gpu_design = sol.designs.iter().find(|d| d.config.engine_set() == vec![Engine::Gpu]);
    if let Some(d) = gpu_design {
        assert!(!d.config.assignments[0].variant.scheme.is_integer()
                || d.config.assignments[0].variant.scheme == carin::zoo::Scheme::Fx8,
                "GPU design must use a GPU-compatible scheme: {}", d.describe(&p));
    }
}

#[test]
fn uc3_a71_dsp_carries_the_vision_model() {
    // Table 8: on A71 the initial design offloads the heavy vision task
    // to a fixed-function engine (DSP/NPU) with a full-integer model.
    let reg = Registry::paper();
    let p = config::use_case("uc3", &reg, &profiles::galaxy_a71()).unwrap();
    let sol = rass::solve(&p);
    let d0 = &sol.designs[0];
    let engines = d0.config.engine_set();
    assert!(
        engines.contains(&Engine::Dsp) || engines.contains(&Engine::Npu)
            || engines.contains(&Engine::Gpu),
        "d0 should use an accelerator, got {}",
        d0.describe(&p)
    );
    // tasks must not all share one engine when the device has four
    assert!(engines.len() >= 2, "d0 serialises both tasks: {}", d0.describe(&p));
}

#[test]
fn rass_dominates_every_baseline_everywhere() {
    let reg = Registry::paper();
    for dev in profiles::all() {
        for uc in ["uc1", "uc2"] {
            let p = config::use_case(uc, &reg, &dev).unwrap();
            let sol = rass::solve(&p);
            let d0 = sol.designs[0].optimality;
            for r in [
                baselines::oodin(&p),
                baselines::single_architecture(&p, true),
                baselines::single_architecture(&p, false),
            ] {
                if let Some(cfg) = r.config {
                    let o = baselines::optimality_of(&p, &cfg);
                    assert!(d0 >= o - 1e-9, "{uc}/{}: {} wins", dev.name, r.label);
                }
            }
        }
    }
}

#[test]
fn multi_dnn_unaware_is_never_better() {
    let reg = Registry::paper();
    for dev in profiles::all() {
        for uc in ["uc3", "uc4"] {
            let p = config::use_case(uc, &reg, &dev).unwrap();
            let sol = rass::solve(&p);
            if let Some(cfg) = baselines::multi_dnn_unaware(&p).config {
                let o = baselines::optimality_of(&p, &cfg);
                assert!(sol.designs[0].optimality >= o - 1e-9, "{uc}/{}", dev.name);
            }
        }
    }
}

#[test]
fn adaptation_trace_recovers_and_respects_policy() {
    let reg = Registry::paper();
    let p = config::use_case("uc3", &reg, &profiles::galaxy_a71()).unwrap();
    let sol = rass::solve(&p);
    let sched = EventSchedule::figure8(p.device.ram_bytes());
    let log = run_trace(&p, sol, sched, 36.0, 0.1, 21);
    assert!(log.switches >= 2, "only {} switches", log.switches);
    // decision latency is effectively zero (paper: eliminates the
    // re-solve overhead entirely)
    assert!(log.mean_decision_ns < 1_000_000.0);
    // memory accounting never goes negative and accuracy stays defined
    for pt in &log.points {
        assert!(pt.mem_mb >= 0.0);
        assert!(pt.accuracy.iter().all(|a| a.is_finite()));
    }
}

#[test]
fn overheat_event_moves_execution_off_the_hot_engine() {
    let reg = Registry::paper();
    let p = config::use_case("uc1", &reg, &profiles::pixel7()).unwrap();
    let sol = rass::solve(&p);
    let d0_engine = sol.designs[0].config.engine_set()[0];
    let sched = EventSchedule::new(vec![(
        2.0,
        Event::Temperature { engine: d0_engine, temp_c: 95.0 },
    )]);
    let log = run_trace(&p, sol, sched, 8.0, 1.0 / 24.0, 5);
    // after the overheat, the active design avoids the hot engine
    // (when an alternative mapping exists)
    let after: Vec<_> = log.points.iter().filter(|pt| pt.t_s > 3.0).collect();
    assert!(!after.is_empty());
    let p2 = config::use_case("uc1", &reg, &profiles::pixel7()).unwrap();
    let sol2 = rass::solve(&p2);
    let has_alternative = sol2
        .designs
        .iter()
        .any(|d| !d.config.engine_set().contains(&d0_engine));
    if has_alternative {
        let moved = after.iter().any(|pt| {
            !sol2.designs[pt.design].config.engine_set().contains(&d0_engine)
        });
        assert!(moved, "execution never left the overheated engine");
    }
}

#[test]
fn storage_reductions_match_paper_direction() {
    // Table 10: CARIn stores a fraction of OODIn's model bytes; the
    // biggest reductions come from the richest zoo (UC1).
    let reg = Registry::paper();
    let rows = carin::harness::tables::table10_storage(&reg);
    let uc1: Vec<_> = rows.iter().filter(|r| r.use_case == "uc1").collect();
    let uc4: Vec<_> = rows.iter().filter(|r| r.use_case == "uc4").collect();
    for r in &uc1 {
        assert!(r.reduction > 3.0, "uc1 reduction only {:.2}", r.reduction);
    }
    // UC4 has one model per task so reductions are modest (paper: 1.66-2.48x)
    for r in &uc4 {
        assert!(r.reduction > 1.0 && r.reduction < 10.0);
    }
    let avg1: f64 = uc1.iter().map(|r| r.reduction).sum::<f64>() / uc1.len() as f64;
    let avg4: f64 = uc4.iter().map(|r| r.reduction).sum::<f64>() / uc4.len() as f64;
    assert!(avg1 > avg4, "uc1 {avg1} should beat uc4 {avg4}");
}

#[test]
fn flapping_fault_signal_is_debounced_end_to_end() {
    // Monitor + RM under a flapping fault signal: hysteresis must absorb
    // the flaps entirely, then a sustained fault causes exactly one
    // fallback and a sustained recovery exactly one switch back.
    use carin::manager::{Monitor, RuntimeManager};
    let reg = Registry::paper();
    let p = config::use_case("uc1", &reg, &profiles::galaxy_s20()).unwrap();
    let sol = rass::solve(&p);
    let engines = sol.policy.engines.clone();
    let mut rm = RuntimeManager::new(sol);
    let mut mon = Monitor::new(engines, 3);
    let faulty = Engine::Cpu;

    // flapping signal: raised and cleared on alternate observations
    for i in 0..200 {
        mon.report_fault(faulty, i % 2 == 0);
        rm.observe(mon.tick(), i as f64 * 0.01);
    }
    assert_eq!(rm.switches.len(), 0, "flapping signal must never switch designs");

    // sustained fault: exactly one fallback switch
    mon.report_fault(faulty, true);
    for i in 0..10 {
        rm.observe(mon.tick(), 2.0 + i as f64 * 0.01);
    }
    assert_eq!(rm.switches.len(), 1, "sustained fault must switch exactly once");
    assert_eq!(rm.fallback_count(), 1);

    // sustained recovery: exactly one switch back to the calm design
    mon.report_fault(faulty, false);
    for i in 0..10 {
        rm.observe(mon.tick(), 3.0 + i as f64 * 0.01);
    }
    assert_eq!(rm.switches.len(), 2, "recovery must switch exactly once");
    assert_eq!(rm.recovery_count(), 1);
    let back = rm.current_design();
    assert!(rm.solution.designs[back].roles.contains(&"d0"));
}

#[test]
fn workload_feeds_serving_channel() {
    // workload -> channel plumbing without PJRT (fast)
    let (tx, rx) = std::sync::mpsc::channel();
    let handles = carin::workload::spawn_producers(
        carin::workload::for_use_case("uc3", 20),
        tx,
        3,
        0.0, // no real-time pacing
    );
    let got: Vec<_> = rx.iter().collect();
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(got.len(), 40);
    assert!(got.iter().any(|r| r.task == 0));
    assert!(got.iter().any(|r| r.task == 1));
}
