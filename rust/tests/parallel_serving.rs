//! Pooled-coordinator integration tests: report accounting invariants
//! and genuine cross-engine execution overlap on the pinned two-engine
//! UC3 solution.

use std::sync::mpsc;

use carin::config;
use carin::coordinator::ServeOptions;
use carin::device::Engine;
use carin::runtime::{synthetic_manifest, StubEngine};
use carin::telemetry::EventKind;
use carin::workload;
use carin::zoo::Registry;

fn run_pooled(
    exec_ms: f64,
    n_per_task: usize,
) -> (carin::coordinator::ServeReport, carin::telemetry::Telemetry) {
    let reg = Registry::paper();
    let sol = config::pinned_uc3_solution(&reg);
    let manifest = synthetic_manifest(&reg);
    let factory =
        move |_: Engine| -> anyhow::Result<StubEngine> { Ok(StubEngine::with_latency(exec_ms)) };
    let mut coord = ServeOptions::new()
        .build_pooled(factory, &reg, &sol, manifest)
        .unwrap();
    let (tx, rx) = mpsc::channel();
    // time_scale 0.0 floods the queues: arrival pacing off, so the run
    // is bounded by execution, not the workload clock
    let producers =
        workload::spawn_producers(workload::for_use_case("uc3", n_per_task), tx, 11, 0.0);
    let report = coord.serve(rx).expect("pooled serve failed");
    for h in producers {
        let _ = h.join();
    }
    let tel = std::mem::replace(
        coord.telemetry_mut(),
        carin::telemetry::Telemetry::new(1),
    );
    (report, tel)
}

#[test]
fn report_invariants_hold_across_the_pool() {
    let submitted = 120usize;
    let (report, tel) = run_pooled(1.0, submitted / 2);

    // conservation: every submitted request is exactly one of
    // completed, failed, timed out or shed
    assert_eq!(
        report.total_requests + report.failed + report.timed_out + report.shed,
        submitted,
        "request taxonomy does not cover the workload"
    );
    let per_task: usize = report.tasks.iter().map(|t| t.completed).sum();
    assert_eq!(per_task, report.total_requests, "task reports disagree with the total");
    assert_eq!(report.tasks.len(), 2);
    for t in &report.tasks {
        assert_eq!(t.failed, 0, "stub engine cannot fail");
        assert!(t.completed > 0, "task {} starved", t.task);
    }

    // goodput is deadline-met completions over the serving window
    let met: usize = report.tasks.iter().map(|t| t.deadline_met).sum();
    assert!(
        (report.goodput_rps * report.window_s - met as f64).abs() < 1e-6,
        "goodput ({}) inconsistent with {met} deadline hits over {}s",
        report.goodput_rps,
        report.window_s
    );
    assert!(report.window_s > 0.0 && report.window_s <= report.wall_s + 1e-6);

    // the merged registry tells the same story as the report
    let r = &tel.registry;
    assert_eq!(r.counter("carin_requests_admitted_total"), submitted as u64);
    assert_eq!(r.counter("carin_requests_completed_total"), report.total_requests as u64);
    assert_eq!(r.counter("carin_requests_failed_total"), report.failed as u64);
    assert_eq!(r.counter("carin_requests_shed_total"), report.shed as u64);
    assert_eq!(tel.recorder.dropped(), 0, "ring buffer wrapped on a 120-request run");

    // per-engine worker series survive the shard merge
    let prom = tel.prometheus();
    for engine in ["CPU", "GPU"] {
        for series in ["carin_engine_busy_ms", "carin_engine_jobs_total"] {
            let needle = format!("{series}{{engine=\"{engine}\"}}");
            assert!(prom.contains(&needle), "missing {needle} in:\n{prom}");
        }
        let depth = format!("carin_engine_queue_depth{{engine=\"{engine}\"}}");
        assert!(prom.contains(&depth), "missing {depth}");
    }
}

#[test]
fn tasks_on_distinct_engines_execute_concurrently() {
    // 5 ms per call makes serialisation measurable: with both queues
    // flooded, non-overlapping execution would be a pool regression
    let (report, tel) = run_pooled(5.0, 40);
    assert_eq!(report.total_requests + report.shed, 80);

    let mut intervals: [Vec<(u64, u64)>; 2] = [Vec::new(), Vec::new()];
    for e in tel.recorder.events() {
        if let EventKind::Completed { task, exec_ns, .. } = e.kind {
            intervals[task as usize].push((e.t_ns.saturating_sub(exec_ns), e.t_ns));
        }
    }
    assert!(!intervals[0].is_empty() && !intervals[1].is_empty());

    let overlaps = intervals[0].iter().any(|&(a0, a1)| {
        intervals[1].iter().any(|&(b0, b1)| a0 < b1 && b0 < a1)
    });
    assert!(
        overlaps,
        "no task-0 execution overlapped any task-1 execution: the pool serialised"
    );
}
