//! Real-PJRT integration tests: load AOT artifacts, execute them on the
//! CPU client, and verify the numbers against the python-side goldens
//! (artifacts/goldens.json) — the end-to-end proof that the HLO-text +
//! npz interchange preserves semantics across the language boundary.
//!
//! The whole suite shares a single PJRT client: xla_extension 0.5.1 is
//! unreliable when several TfrtCpuClients are created and destroyed in
//! one process (teardown segfaults), so one `#[test]` drives every
//! scenario sequentially over one engine.
//!
//! The suite skips (passes trivially) when `make artifacts` has not run.

use std::path::PathBuf;

use carin::runtime::engine::{zero_input, InferenceEngine, Tensor};
use carin::runtime::{load_manifest, ArtifactMeta};
use carin::util::json::Json;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn goldens() -> Option<std::collections::BTreeMap<String, Vec<f64>>> {
    let path = artifacts_dir().join("goldens.json");
    let text = std::fs::read_to_string(path).ok()?;
    match Json::parse(&text).ok()? {
        Json::Obj(m) => Some(
            m.into_iter()
                .map(|(k, v)| {
                    let vals = v
                        .as_arr()
                        .unwrap()
                        .iter()
                        .map(|x| x.as_f64().unwrap())
                        .collect();
                    (k, vals)
                })
                .collect(),
        ),
        _ => None,
    }
}

fn find<'a>(manifest: &'a [ArtifactMeta], stem: &str) -> &'a ArtifactMeta {
    manifest.iter().find(|m| m.stem == stem).unwrap_or_else(|| panic!("{stem} missing"))
}

#[test]
fn pjrt_engine_suite() {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let manifest = load_manifest(&dir).expect("manifest parses");
    let mut engine = InferenceEngine::cpu().expect("PJRT CPU client");
    assert!(engine.platform().to_lowercase().contains("cpu"));

    load_and_infer_one_model_per_family(&mut engine, &manifest);
    outputs_match_python_goldens(&mut engine, &manifest);
    repeated_inference_is_deterministic(&mut engine, &manifest);
    infer_validates_shape_and_dtype(&mut engine, &manifest);
    unload_frees_model(&mut engine, &manifest);
    measure_returns_positive_latencies(&mut engine, &manifest);
    quantised_variants_agree_on_top1(&mut engine, &manifest);
}

fn load_and_infer_one_model_per_family(engine: &mut InferenceEngine, manifest: &[ArtifactMeta]) {
    for stem in ["cnn_s_fp32", "bert_s_fp32", "yamnet_lite_fp32", "face_gender_fp32"] {
        let meta = find(manifest, stem);
        engine.load(meta).unwrap_or_else(|e| panic!("{stem}: {e}"));
        let out = engine.infer(stem, &zero_input(meta)).unwrap();
        assert_eq!(out.len(), meta.outputs[0].numel(), "{stem} output size");
        let v = out.to_f32(None);
        assert!(v.iter().all(|x| x.is_finite()), "{stem} non-finite output");
    }
}

fn outputs_match_python_goldens(engine: &mut InferenceEngine, manifest: &[ArtifactMeta]) {
    let Some(gold) = goldens() else {
        eprintln!("skipping goldens: goldens.json missing");
        return;
    };
    // one artifact per (family x scheme class) covers every code path:
    // f32, f16 dequant, dr8, fx8 (fused kernel), ffx8 int8 I/O, int32 ids.
    let picks = [
        "cnn_s_fp32", "cnn_s_fp16", "cnn_s_dr8", "cnn_s_fx8", "cnn_s_ffx8",
        "bert_s_fp32", "bert_s_ffx8", "yamnet_lite_dr8", "face_eth_fx8",
        "scene_m_fp16", "vit_xs_fp32",
    ];
    for stem in picks {
        let meta = find(manifest, stem);
        let want = gold.get(stem).unwrap_or_else(|| panic!("no golden for {stem}"));
        engine.load(meta).unwrap();
        let out = engine.infer(stem, &zero_input(meta)).unwrap();
        let got = out.to_f32(None);
        for (i, (&g, &w)) in got.iter().zip(want.iter()).enumerate() {
            let w = w as f32;
            let tol = if stem.ends_with("ffx8") {
                1.001 // one int8 quantisation step
            } else {
                2e-3 * w.abs().max(1.0)
            };
            assert!((g - w).abs() <= tol, "{stem}[{i}]: rust {g} vs python {w}");
        }
    }
}

fn repeated_inference_is_deterministic(engine: &mut InferenceEngine, manifest: &[ArtifactMeta]) {
    let meta = find(manifest, "cnn_s_ffx8");
    engine.load(meta).unwrap();
    let input = carin::runtime::engine::random_input(meta, 3);
    let a = engine.infer("cnn_s_ffx8", &input).unwrap().to_f32(None);
    let b = engine.infer("cnn_s_ffx8", &input).unwrap().to_f32(None);
    assert_eq!(a, b);
}

fn infer_validates_shape_and_dtype(engine: &mut InferenceEngine, manifest: &[ArtifactMeta]) {
    let meta = find(manifest, "cnn_s_fp32");
    engine.load(meta).unwrap();
    // wrong dtype
    let bad = Tensor::I8(vec![0; meta.input.numel()].into());
    assert!(engine.infer("cnn_s_fp32", &bad).is_err());
    // wrong size
    let bad = Tensor::F32(vec![0.0; 3].into());
    assert!(engine.infer("cnn_s_fp32", &bad).is_err());
    // unknown model
    let ok = zero_input(meta);
    assert!(engine.infer("nope", &ok).is_err());
}

fn unload_frees_model(engine: &mut InferenceEngine, manifest: &[ArtifactMeta]) {
    let meta = find(manifest, "face_age_fp32");
    engine.load(meta).unwrap();
    assert!(engine.is_loaded("face_age_fp32"));
    engine.unload("face_age_fp32");
    assert!(!engine.is_loaded("face_age_fp32"));
    assert!(engine.infer("face_age_fp32", &zero_input(meta)).is_err());
}

fn measure_returns_positive_latencies(engine: &mut InferenceEngine, manifest: &[ArtifactMeta]) {
    let meta = find(manifest, "face_gender_ffx8");
    engine.load(meta).unwrap();
    let lat = engine
        .measure("face_gender_ffx8", &zero_input(meta), 2, 10)
        .unwrap();
    assert_eq!(lat.len(), 10);
    assert!(lat.iter().all(|&x| x > 0.0));
}

fn quantised_variants_agree_on_top1(engine: &mut InferenceEngine, manifest: &[ArtifactMeta]) {
    // fp32 and fx8 variants of the same model must rank classes the same
    // way on a random input (accuracy preservation, Tables 2-5 premise).
    let f32m = find(manifest, "scene_s_fp32");
    engine.load(f32m).unwrap();
    engine.load(find(manifest, "scene_s_fx8")).unwrap();
    let mut agree = 0;
    for seed in 0..5 {
        let input = carin::runtime::engine::random_input(f32m, seed);
        let a = engine.infer("scene_s_fp32", &input).unwrap().argmax();
        let b = engine.infer("scene_s_fx8", &input).unwrap().argmax();
        agree += (a == b) as u32;
    }
    assert!(agree >= 4, "top-1 agreement {agree}/5");
}
