//! Proof of the zero-copy hot path (ROADMAP "Memory path"): a counting
//! global allocator pins down that the stub single-loop serving path
//! performs zero heap allocations per request once warm, plus property
//! tests of the [`carin::util::BufferPool`] lease/return contract.
//!
//! Methodology: heap traffic is counted process-wide, so (a) every test
//! in this binary serializes on one mutex, keeping foreign allocations
//! out of the measured window, and (b) the measured quantity is the
//! *difference* in allocation count between a small run and a 4x run —
//! per-run setup (stat vectors, report strings, summaries) cancels out,
//! and anything that allocated per request would show up ~3x the small
//! run's request count.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Mutex};
use std::time::Instant;

use carin::config;
use carin::coordinator::serve::ServeRequest;
use carin::coordinator::ServeOptions;
use carin::runtime::{synthetic_manifest, StubEngine};
use carin::util::{BufferPool, Rng};
use carin::zoo::Registry;

struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

/// Serializes every test in this binary so nothing else allocates
/// inside a measured window.
static GATE: Mutex<()> = Mutex::new(());

fn allocs() -> u64 {
    ALLOC_CALLS.load(Ordering::Relaxed)
}

/// Feed `per_task` requests per uc3 task into a fresh channel, close it,
/// and return the receiver (the serve loop then drains without blocking
/// on producers, and the channel-node allocations land outside the
/// measured window).
fn preloaded_workload(per_task: usize, n_tasks: usize) -> mpsc::Receiver<ServeRequest> {
    let (tx, rx) = mpsc::channel();
    let now = Instant::now();
    for task in 0..n_tasks {
        for i in 0..per_task {
            tx.send(ServeRequest {
                task,
                id: (task as u64) << 48 | i as u64,
                submitted: now,
                deadline: None,
            })
            .unwrap();
        }
    }
    rx
}

#[test]
fn steady_state_serving_does_not_allocate_per_request() {
    let _gate = GATE.lock().unwrap();
    const N: usize = 300;
    let n_tasks = 2; // uc3: scene + audio

    let reg = Registry::paper();
    let sol = config::pinned_uc3_solution(&reg);
    let manifest = synthetic_manifest(&reg);
    let mut coord = ServeOptions::new()
        .expected_requests(4 * N)
        .build_with_engine(StubEngine::new(), &reg, &sol, manifest)
        .unwrap();

    // Warmup: populate pool slots, intern metric names, fill the event
    // ring. Everything that allocates once does it here.
    let rx = preloaded_workload(N, n_tasks);
    coord.serve(rx).unwrap();

    // Measured small run.
    let rx = preloaded_workload(N, n_tasks);
    let a0 = allocs();
    coord.serve(rx).unwrap();
    let small = allocs() - a0;

    // Measured 4x run: 3x more requests than the small run.
    let rx = preloaded_workload(4 * N, n_tasks);
    let a0 = allocs();
    coord.serve(rx).unwrap();
    let large = allocs() - a0;

    // Per-run bookkeeping (fresh stat vectors, report strings, summary
    // buffers) is identical between the runs; a single allocation per
    // request would add >= 3*N*n_tasks = 1800 calls to the large run.
    let delta = large.saturating_sub(small);
    assert!(
        delta <= 100,
        "steady-state serving allocates per request: \
         {small} allocs for {N}/task vs {large} for {}/task (delta {delta})",
        4 * N
    );

    // And the pool actually carried the traffic.
    let ps = coord.buffer_pool_stats();
    assert!(
        ps.hit_rate() >= 0.95,
        "pool hit rate {:.3} below 0.95 ({ps:?})",
        ps.hit_rate()
    );
}

#[test]
fn disabled_pool_allocates_per_request() {
    // The counting allocator can tell the copying baseline apart from
    // the pooled path: with pooling off, the same workload's allocation
    // count scales with the request count.
    let _gate = GATE.lock().unwrap();
    const N: usize = 150;
    let n_tasks = 2;

    let reg = Registry::paper();
    let sol = config::pinned_uc3_solution(&reg);
    let manifest = synthetic_manifest(&reg);
    let mut coord = ServeOptions::new()
        .pool_slots(0)
        .expected_requests(4 * N)
        .build_with_engine(StubEngine::new(), &reg, &sol, manifest)
        .unwrap();

    let rx = preloaded_workload(N, n_tasks);
    coord.serve(rx).unwrap();

    let rx = preloaded_workload(N, n_tasks);
    let a0 = allocs();
    coord.serve(rx).unwrap();
    let small = allocs() - a0;

    let rx = preloaded_workload(4 * N, n_tasks);
    let a0 = allocs();
    coord.serve(rx).unwrap();
    let large = allocs() - a0;

    // 3*N*n_tasks = 900 extra requests, each leasing an unpooled input
    // buffer (StubEngine's internal output pool stays disabled-free).
    assert!(
        large.saturating_sub(small) >= 3 * N as u64,
        "copying baseline unexpectedly allocation-free: {small} vs {large}"
    );
}

#[test]
fn pool_reuses_buffers_and_zero_pads() {
    let _gate = GATE.lock().unwrap();
    let mut rng = Rng::new(11);
    for _ in 0..200 {
        let pool = BufferPool::new(4);
        let len = 1 + rng.below(256);
        let first = pool.lease_with(len, |v| v.push(1.5));
        let ptr = first.as_slice().as_ptr();
        drop(first);

        // a second lease of no greater length must recycle the slot and
        // present fill + zero padding, never stale contents
        let shorter = 1 + rng.below(len);
        let filled = rng.below(shorter + 1);
        let b = pool.lease_with(shorter, |v| v.extend((0..filled).map(|i| i as f32 + 1.0)));
        assert!(std::ptr::eq(ptr, b.as_slice().as_ptr()), "slot not recycled");
        assert_eq!(b.len(), shorter);
        for (i, &x) in b.iter().enumerate() {
            let want = if i < filled { i as f32 + 1.0 } else { 0.0 };
            assert_eq!(x, want, "lease len {shorter} fill {filled} index {i}");
        }
    }
}

#[test]
fn pool_counters_partition_leases() {
    let _gate = GATE.lock().unwrap();
    let mut rng = Rng::new(29);
    for _ in 0..100 {
        let pool = BufferPool::new(1 + rng.below(8));
        let mut live = Vec::new();
        let mut leases = 0u64;
        for _ in 0..50 {
            if !live.is_empty() && rng.below(3) == 0 {
                live.swap_remove(rng.below(live.len()));
            } else {
                live.push(pool.lease_zeroed(1 + rng.below(64)));
                leases += 1;
            }
        }
        drop(live);
        pool.sweep_returns();
        let s = pool.stats();
        // every lease is exactly one hit or one miss, and nothing can
        // return more often than it was leased
        assert_eq!(s.hits + s.misses, leases, "{s:?}");
        assert!(s.returns <= leases, "{s:?}");
    }
}

#[test]
fn leased_buffers_are_f32_aligned() {
    let _gate = GATE.lock().unwrap();
    let pool = BufferPool::new(4);
    for len in [1usize, 3, 16, 257] {
        let b = pool.lease_zeroed(len);
        assert_eq!(
            b.as_slice().as_ptr() as usize % std::mem::align_of::<f32>(),
            0,
            "len {len}"
        );
    }
}
