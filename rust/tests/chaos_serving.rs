//! Chaos acceptance test: the full serving stack (workload -> channel ->
//! coordinator -> router/batcher -> executor) survives injected faults.
//!
//! 10% transient inference faults across every model plus a hard outage
//! window on the calm design's route: the run must complete with zero
//! process-level errors, keep the failure rate of admitted requests
//! under 5%, take at least one fallback design switch while the route is
//! out, and recover to the calm design once health probes pass.

use std::sync::mpsc;

use carin::config;
use carin::coordinator::ServingCoordinator;
use carin::device::profiles;
use carin::moo::rass::{self, EnvState};
use carin::runtime::{synthetic_manifest, FaultInjector, FaultSpec, StubEngine};
use carin::workload;
use carin::zoo::Registry;

/// Artifact stem routed for `task` under the policy's calm design.
fn calm_stem(reg: &Registry, sol: &carin::moo::Solution, task: usize) -> String {
    let d0 = sol.policy.design_for(EnvState::calm());
    let a = &sol.designs[d0].config.assignments[task];
    format!("{}_{}", reg.models[a.variant.model].artifact, a.variant.scheme.name())
}

#[test]
fn uc1_serving_survives_transient_faults_and_an_outage() {
    let reg = Registry::paper();
    let dev = profiles::galaxy_s20();
    let p = config::use_case("uc1", &reg, &dev).unwrap();
    let sol = rass::solve(&p);
    let manifest = synthetic_manifest(&reg);

    let mut inj = FaultInjector::new(StubEngine::new(), 42);
    inj.set_default(FaultSpec::transient(0.10));
    // hard outage on the calm design's route: calls 30..=44 all fail,
    // forcing supervision to raise the fault signal and fall back
    let stem = calm_stem(&reg, &sol, 0);
    inj.set_for(&stem, FaultSpec::transient(0.10).with_outage(30, 44));

    let mut coord =
        ServingCoordinator::with_engine(inj, &reg, &sol, manifest).expect("preload");

    let n = 240;
    let (tx, rx) = mpsc::channel();
    let producers =
        workload::spawn_producers(workload::for_use_case("uc1", n), tx, 11, 0.0);
    // zero process-level errors: serve() must return Ok under injection
    let report = coord.serve(rx).expect("serving must survive injected faults");
    for h in producers {
        h.join().unwrap();
    }

    let admitted = report.total_requests + report.failed;
    assert_eq!(admitted + report.shed, n, "every request accounted for");
    assert!(report.total_requests > 0, "nothing completed");
    // >= 95% of admitted (non-shed) requests succeed despite 10%
    // transients (retries absorb them) and the outage (fallback bounds it)
    let fail_rate = report.failed as f64 / admitted as f64;
    assert!(fail_rate <= 0.05, "failure rate {fail_rate:.3} > 5%");
    // retries actually engaged on transients
    assert!(report.retried > 0, "no retry ever fired under 10% transients");
    // the outage must force a fallback switch and a later recovery
    assert!(
        report.fallback_switches >= 1,
        "outage never caused a fallback switch: {report:?}"
    );
    assert!(
        report.recovered_switches >= 1,
        "fault signal never cleared after the outage: {report:?}"
    );
    // the run ends back on the calm design
    let d0 = sol.policy.design_for(EnvState::calm());
    assert_eq!(coord.current_design(), d0, "did not recover to the calm design");
    // goodput: completed-within-deadline requests were measured
    assert!(report.goodput_rps > 0.0);
    // the injector really injected
    assert!(coord.engine().stats.injected_errors > 0);
}

#[test]
fn clean_run_sheds_and_fails_nothing() {
    let reg = Registry::paper();
    let dev = profiles::galaxy_s20();
    let p = config::use_case("uc1", &reg, &dev).unwrap();
    let sol = rass::solve(&p);
    let manifest = synthetic_manifest(&reg);

    let mut coord =
        ServingCoordinator::with_engine(StubEngine::new(), &reg, &sol, manifest)
            .expect("preload");
    let (tx, rx) = mpsc::channel();
    let producers =
        workload::spawn_producers(workload::for_use_case("uc1", 80), tx, 3, 0.0);
    let report = coord.serve(rx).unwrap();
    for h in producers {
        h.join().unwrap();
    }
    assert_eq!(report.total_requests, 80);
    assert_eq!(report.failed, 0);
    assert_eq!(report.shed, 0);
    assert_eq!(report.retried, 0);
    assert_eq!(report.fallback_switches, 0);
    assert_eq!(report.recovered_switches, 0);
    // with no deadline misses goodput equals throughput
    assert!((report.goodput_rps - report.throughput_rps).abs() < 1e-9);
}
