//! Chaos acceptance test: the full serving stack (workload -> channel ->
//! coordinator -> router/batcher -> executor) survives injected faults.
//!
//! 10% transient inference faults across every model plus a hard outage
//! window on the calm design's route: the run must complete with zero
//! process-level errors, keep the failure rate of admitted requests
//! under 5%, take at least one fallback design switch while the route is
//! out, and recover to the calm design once health probes pass.
//!
//! The telemetry recorder must tell the same story in order: fault
//! raised -> fallback switch -> health probe -> recovery switch, and the
//! JSONL / Prometheus exports must be parseable and consistent with the
//! report.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use carin::config;
use carin::coordinator::{FaultPolicy, ServeOptions};
use carin::device::profiles;
use carin::moo::rass::{self, EnvState};
use carin::runtime::{synthetic_manifest, FaultInjector, FaultSpec, StubEngine, Watchdog};
use carin::telemetry::EventKind;
use carin::util::json::Json;
use carin::workload;
use carin::zoo::Registry;

/// Artifact stem routed for `task` under the policy's calm design.
fn calm_stem(reg: &Registry, sol: &carin::moo::Solution, task: usize) -> String {
    let d0 = sol.policy.design_for(EnvState::calm());
    let a = &sol.designs[d0].config.assignments[task];
    format!("{}_{}", reg.models[a.variant.model].artifact, a.variant.scheme.name())
}

#[test]
fn uc1_serving_survives_transient_faults_and_an_outage() {
    let reg = Registry::paper();
    let dev = profiles::galaxy_s20();
    let p = config::use_case("uc1", &reg, &dev).unwrap();
    let sol = rass::solve(&p);
    let manifest = synthetic_manifest(&reg);

    let mut inj = FaultInjector::new(StubEngine::new(), 42);
    inj.set_default(FaultSpec::transient(0.10));
    // hard outage on the calm design's route: calls 30..=44 all fail,
    // forcing supervision to raise the fault signal and fall back
    let stem = calm_stem(&reg, &sol, 0);
    inj.set_for(&stem, FaultSpec::transient(0.10).with_outage(30, 44));

    let mut coord = ServeOptions::new()
        .build_with_engine(inj, &reg, &sol, manifest)
        .expect("preload");

    let n = 240;
    let (tx, rx) = mpsc::channel();
    let producers =
        workload::spawn_producers(workload::for_use_case("uc1", n), tx, 11, 0.0);
    // zero process-level errors: serve() must return Ok under injection
    let report = coord.serve(rx).expect("serving must survive injected faults");
    for h in producers {
        h.join().unwrap();
    }

    let admitted = report.total_requests + report.failed + report.timed_out;
    assert_eq!(admitted + report.shed, n, "every request accounted for");
    assert!(report.total_requests > 0, "nothing completed");
    // >= 95% of admitted (non-shed) requests succeed despite 10%
    // transients (retries absorb them) and the outage (fallback bounds it)
    let fail_rate = report.failed as f64 / admitted as f64;
    assert!(fail_rate <= 0.05, "failure rate {fail_rate:.3} > 5%");
    // retries actually engaged on transients
    assert!(report.retried > 0, "no retry ever fired under 10% transients");
    // the outage must force a fallback switch and a later recovery
    assert!(
        report.fallback_switches >= 1,
        "outage never caused a fallback switch: {report:?}"
    );
    assert!(
        report.recovered_switches >= 1,
        "fault signal never cleared after the outage: {report:?}"
    );
    // the run ends back on the calm design
    let d0 = sol.policy.design_for(EnvState::calm());
    assert_eq!(coord.current_design(), d0, "did not recover to the calm design");
    // goodput: completed-within-deadline requests were measured
    assert!(report.goodput_rps > 0.0);
    // the injector really injected
    assert!(coord.engine().stats.injected_errors > 0);

    // --- telemetry: the recorder must replay the supervision story in
    // causal order: fault raised -> fallback switch -> probe -> recovery
    let tel = coord.telemetry();
    let events = tel.recorder.events();
    assert!(!events.is_empty(), "no telemetry events recorded");
    for w in events.windows(2) {
        assert!(w[0].seq < w[1].seq, "events out of sequence order");
        assert!(w[0].t_ns <= w[1].t_ns, "event timestamps regressed");
    }
    let after = |from: usize, what: &str, pred: fn(&EventKind) -> bool| -> usize {
        events[from..]
            .iter()
            .position(|e| pred(&e.kind))
            .map(|i| i + from)
            .unwrap_or_else(|| panic!("no {what} event at/after index {from}"))
    };
    let i_fault = after(0, "fault_raised", |k| matches!(k, EventKind::FaultRaised { .. }));
    let i_fall = after(i_fault, "fallback switch", |k| {
        matches!(k, EventKind::Switch { fallback: true, .. })
    });
    let i_probe = after(i_fall, "probe", |k| matches!(k, EventKind::Probe { .. }));
    let i_recov = after(i_probe, "recovery switch", |k| {
        matches!(k, EventKind::Switch { fallback: false, .. })
    });
    assert!(i_fault < i_fall && i_fall < i_probe && i_probe < i_recov);
    // the fallback switch saw the raised fault in its audit bits
    if let EventKind::Switch { bad_mask, .. } = events[i_fall].kind {
        assert!(bad_mask != 0, "fallback switch recorded a calm bad_mask");
    }
    // the recovery switch saw a clean environment
    if let EventKind::Switch { bad_mask, to, .. } = events[i_recov].kind {
        assert_eq!(bad_mask, 0, "recovery switch recorded a raised bad_mask");
        assert_eq!(to as usize, d0, "recovery switch did not target the calm design");
    }

    // metric registry agrees with the report
    let m = &tel.registry;
    assert_eq!(m.counter("carin_requests_completed_total"), report.total_requests as u64);
    assert_eq!(m.counter("carin_requests_failed_total"), report.failed as u64);
    assert_eq!(m.counter("carin_requests_shed_total"), report.shed as u64);
    assert_eq!(m.counter("carin_switches_fallback_total"), report.fallback_switches as u64);
    assert_eq!(m.counter("carin_switches_recovery_total"), report.recovered_switches as u64);
    assert!(m.counter("carin_faults_raised_total") >= 1);
    assert!(m.counter("carin_probes_total") >= 1);
    let e2e = m.histogram("carin_e2e_latency_ms").expect("e2e histogram missing");
    assert_eq!(e2e.count(), report.total_requests as u64);

    // serving window: positive and within the measured wall clock
    assert!(report.window_s > 0.0, "window never opened");
    assert!(report.window_s <= report.wall_s + 1e-6, "window exceeds wall clock");
    assert_eq!(m.gauge("carin_window_s"), Some(report.window_s));

    // every JSONL line is standalone-parseable JSON with the event schema
    let jsonl = tel.events_jsonl();
    assert_eq!(jsonl.lines().count(), events.len());
    for line in jsonl.lines() {
        let j = Json::parse(line).expect("telemetry JSONL line is not valid JSON");
        assert!(j.get("event").and_then(Json::as_str).is_some(), "line lacks event: {line}");
        assert!(j.get("t_ns").is_some(), "line lacks t_ns: {line}");
    }

    // the Prometheus snapshot exposes the request counters and at least
    // one latency histogram with cumulative buckets
    let prom = tel.prometheus();
    assert!(prom.contains("carin_requests_admitted_total"));
    assert!(prom.contains("carin_requests_completed_total"));
    assert!(prom.contains("carin_e2e_latency_ms_bucket"));
    assert!(prom.contains("le=\"+Inf\""));
    assert!(prom.contains("carin_e2e_latency_ms_count"));
}

/// Backoff isolation (the pool's reason to exist): a hard outage on one
/// engine's route must not stall the other engine's task. The pinned
/// two-engine solution has a single design, so no fallback can rescue
/// the faulted route — the CPU worker grinds through retries and
/// failures for the whole run while the GPU worker must stay at full
/// service, interleaved in time with the outage.
#[test]
fn outage_on_one_engine_does_not_stall_the_other() {
    let reg = Registry::paper();
    let sol = config::pinned_uc3_solution(&reg);
    let manifest = synthetic_manifest(&reg);

    // task 0's route on the CPU worker: dead from its 10th call onward
    let stem0 = calm_stem(&reg, &sol, 0);
    let factory = move |_: carin::device::Engine| -> anyhow::Result<FaultInjector<StubEngine>> {
        let mut inj = FaultInjector::new(StubEngine::with_latency(1.0), 9);
        inj.set_for(&stem0, FaultSpec::transient(0.0).with_outage(10, 1_000_000));
        Ok(inj)
    };
    let mut coord = ServeOptions::new()
        .build_pooled(factory, &reg, &sol, manifest)
        .expect("preload");

    let n = 120;
    let (tx, rx) = mpsc::channel();
    let producers =
        workload::spawn_producers(workload::for_use_case("uc3", n), tx, 17, 0.0);
    let report = coord.serve(rx).expect("pool must survive a one-engine outage");
    for h in producers {
        h.join().unwrap();
    }

    let t0 = &report.tasks[0];
    let t1 = &report.tasks[1];
    // the healthy engine's task is untouched by its neighbour's outage
    assert_eq!(t1.completed, n, "GPU task lost requests to the CPU outage");
    assert_eq!(t1.failed, 0);
    assert_eq!(t1.shed, 0);
    // the faulted route really did burn
    assert!(t0.failed > 0, "outage injected but task 0 never failed");
    assert!(
        coord.fault_stats().map(|s| s.injected_errors).unwrap_or(0) > 0,
        "injector counters lost across the worker boundary"
    );
    // supervision saw the repeated failures and raised the fault signal
    assert!(coord.telemetry().registry.counter("carin_faults_raised_total") >= 1);

    // temporal isolation: healthy-task completions land *during* the
    // outage, not just after the faulted queue drained
    let events = coord.telemetry().recorder.events();
    let fail_times: Vec<u64> = events
        .iter()
        .filter(|e| matches!(e.kind, EventKind::Failed { task: 0, .. }))
        .map(|e| e.t_ns)
        .collect();
    assert!(!fail_times.is_empty());
    let (first_fail, last_fail) =
        (*fail_times.first().unwrap(), *fail_times.last().unwrap());
    let concurrent_completions = events
        .iter()
        .filter(|e| matches!(e.kind, EventKind::Completed { task: 1, .. }))
        .filter(|e| e.t_ns > first_fail && e.t_ns < last_fail)
        .count();
    assert!(
        concurrent_completions > 0,
        "no GPU completion overlapped the CPU outage window [{first_fail}, {last_fail}] ns"
    );
}

/// Watchdog supervision end to end (the tentpole acceptance test): one
/// engine's route hangs — calls stall, they do not error — so only the
/// per-call deadline can turn the stall into a signal. The pooled
/// coordinator must classify the stalls as timeouts, raise the fault
/// within the debounce window, take the hand-authored fallback design,
/// keep the healthy engine draining throughout, and switch back to the
/// calm design once probes pass after the hang window ends.
#[test]
fn hung_engine_times_out_faults_over_and_recovers() {
    let reg = Registry::paper();
    let sol = config::pinned_uc3_fallback_solution(&reg);
    let manifest = synthetic_manifest(&reg);

    // task 0's CPU route hangs every call for 10 s of wall clock — far
    // past any deadline — until `hang_until`. The wall-clock window (not
    // a call-index one) survives watchdog respawns: a fresh injector has
    // reset call counts, but the clock keeps running, so probes really
    // do start succeeding once the window closes.
    let stem0 = calm_stem(&reg, &sol, 0);
    let hang_until = Instant::now() + Duration::from_millis(400);
    let factory = move |_: carin::device::Engine| {
        let stem = stem0.clone();
        Watchdog::new(move || {
            let mut inj = FaultInjector::new(StubEngine::with_latency(1.0), 23);
            inj.set_for(&stem, FaultSpec::transient(0.0).with_hang_until(hang_until, 10_000.0));
            Ok(inj)
        })
    };
    // tight supervision so the test stays fast: 20 ms deadlines, one
    // attempt per call, fault after 2 consecutive terminal timeouts
    let policy = FaultPolicy {
        max_attempts: 1,
        fault_threshold: 2,
        probe_interval: 4,
        timeout_mult: 2.0,
        timeout_floor: Duration::from_millis(20),
        ..FaultPolicy::default()
    };
    let mut coord = ServeOptions::new()
        .fault_policy(policy)
        .latency_slo_ms(10.0)
        .build_pooled(factory, &reg, &sol, manifest)
        .expect("preload");

    // paced arrivals (5% of real time) so admissions — and with them
    // probes and monitor ticks — keep flowing well past the hang window
    let n = 60;
    let (tx, rx) = mpsc::channel();
    let producers =
        workload::spawn_producers(workload::for_use_case("uc3", n), tx, 29, 0.05);
    let report = coord.serve(rx).expect("pool must survive a hung engine");
    for h in producers {
        h.join().unwrap();
    }

    // timeouts are their own terminal bucket, disjoint from failures
    assert!(report.timed_out > 0, "hung route never produced a timeout: {report:?}");
    assert_eq!(
        report.total_requests + report.failed + report.timed_out + report.shed,
        2 * n,
        "every request accounted for"
    );
    let t1 = &report.tasks[1];
    assert_eq!(t1.failed, 0, "healthy GPU task failed");
    assert_eq!(t1.timed_out, 0, "healthy GPU task timed out");

    // supervision story, in causal order: a timeout classified, the
    // fault raised, the fallback design taken, a probe answered, the
    // fault cleared, the calm design restored
    let events = coord.telemetry().recorder.events();
    let after = |from: usize, what: &str, pred: fn(&EventKind) -> bool| -> usize {
        events[from..]
            .iter()
            .position(|e| pred(&e.kind))
            .map(|i| i + from)
            .unwrap_or_else(|| panic!("no {what} event at/after index {from}"))
    };
    let i_to = after(0, "timed_out", |k| matches!(k, EventKind::TimedOut { task: 0, .. }));
    let i_fault = after(i_to, "fault_raised", |k| matches!(k, EventKind::FaultRaised { .. }));
    let i_fall = after(i_fault, "fallback switch", |k| {
        matches!(k, EventKind::Switch { fallback: true, .. })
    });
    let i_probe = after(i_fall, "probe", |k| matches!(k, EventKind::Probe { .. }));
    let i_clear = after(i_probe, "fault_cleared", |k| {
        matches!(k, EventKind::FaultCleared { .. })
    });
    let i_recov = after(i_clear, "recovery switch", |k| {
        matches!(k, EventKind::Switch { fallback: false, .. })
    });
    assert!(report.fallback_switches >= 1 && report.recovered_switches >= 1);
    // the run ends back on the calm design: probes healed the hang
    assert_eq!(coord.current_design(), 0, "did not recover to the calm design");
    // the fallback switch targeted the hand-authored all-GPU design
    if let EventKind::Switch { to, .. } = events[i_fall].kind {
        assert_eq!(to, 1, "fallback switch did not target the cpu-fallback design");
    }

    // cross-engine isolation: the GPU queue kept draining between the
    // first timeout and the fault clearing — the hung CPU route never
    // stalled its neighbour
    let (t_first, t_clear) = (events[i_to].t_ns, events[i_clear].t_ns);
    let concurrent = events
        .iter()
        .filter(|e| matches!(e.kind, EventKind::Completed { task: 1, .. }))
        .filter(|e| e.t_ns > t_first && e.t_ns < t_clear)
        .count();
    assert!(
        concurrent > 0,
        "no GPU completion overlapped the CPU hang window [{t_first}, {t_clear}] ns"
    );
    assert!(i_clear < i_recov, "recovery switch preceded the fault clearing");

    // counters: per-attempt engine timeouts cover the per-request
    // terminal ones, and both survive the worker-shard merge into the
    // Prometheus export
    let m = &coord.telemetry().registry;
    assert_eq!(m.counter("carin_requests_timed_out_total"), report.timed_out as u64);
    assert!(m.counter("carin_engine_timeouts_total") >= report.timed_out as u64);
    let prom = coord.telemetry().prometheus();
    assert!(prom.contains("carin_engine_timeouts_total"));
    assert!(prom.contains("carin_requests_timed_out_total"));
}

#[test]
fn clean_run_sheds_and_fails_nothing() {
    let reg = Registry::paper();
    let dev = profiles::galaxy_s20();
    let p = config::use_case("uc1", &reg, &dev).unwrap();
    let sol = rass::solve(&p);
    let manifest = synthetic_manifest(&reg);

    let mut coord = ServeOptions::new()
        .build_with_engine(StubEngine::new(), &reg, &sol, manifest)
        .expect("preload");
    let (tx, rx) = mpsc::channel();
    let producers =
        workload::spawn_producers(workload::for_use_case("uc1", 80), tx, 3, 0.0);
    let report = coord.serve(rx).unwrap();
    for h in producers {
        h.join().unwrap();
    }
    assert_eq!(report.total_requests, 80);
    assert_eq!(report.failed, 0);
    assert_eq!(report.timed_out, 0);
    assert_eq!(report.shed, 0);
    assert_eq!(report.retried, 0);
    assert_eq!(report.fallback_switches, 0);
    assert_eq!(report.recovered_switches, 0);
    // with no deadline misses goodput equals throughput
    assert!((report.goodput_rps - report.throughput_rps).abs() < 1e-9);

    // telemetry on a clean run: window open, ring buffer far from full,
    // and no supervision-loop events ever fired
    let tel = coord.telemetry();
    assert!(report.window_s > 0.0, "window never opened");
    assert!(report.window_s <= report.wall_s + 1e-6, "window exceeds wall clock");
    assert_eq!(tel.recorder.dropped(), 0, "ring buffer wrapped on an 80-request run");
    assert!(tel.recorder.events().iter().all(|e| !matches!(
        e.kind,
        EventKind::FaultRaised { .. }
            | EventKind::FaultCleared { .. }
            | EventKind::Probe { .. }
            | EventKind::Switch { .. }
    )));
    assert_eq!(tel.registry.counter("carin_requests_admitted_total"), 80);
    assert_eq!(tel.registry.counter("carin_requests_completed_total"), 80);
}
