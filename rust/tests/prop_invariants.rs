//! Property-based tests over randomized inputs (mini in-tree property
//! harness — proptest is not in the offline registry). Each property runs
//! against many seeded random cases; failures print the offending seed.

use carin::device::{profiles, Proc};
use carin::moo::pareto::{dominates, front, non_dominated_sort};
use carin::moo::rass::EnvState;
use carin::moo::{rass, Metric, Statistic};
use carin::profiler::stats::{contention_factor, scale};
use carin::util::{Rng, Summary};
use carin::zoo::Registry;

/// Run a property over `n` seeded cases.
fn forall(n: u64, prop: impl Fn(&mut Rng) -> Result<(), String>) {
    for seed in 0..n {
        let mut rng = Rng::new(seed * 0x9E37 + 1);
        if let Err(msg) = prop(&mut rng) {
            panic!("property failed at seed {seed}: {msg}");
        }
    }
}

fn random_vectors(rng: &mut Rng, n: usize, d: usize) -> Vec<Vec<f64>> {
    (0..n)
        .map(|_| (0..d).map(|_| rng.range(-50.0, 50.0)).collect())
        .collect()
}

#[test]
fn prop_dominance_is_irreflexive_and_antisymmetric() {
    forall(200, |rng| {
        let d = 2 + rng.below(4);
        let higher: Vec<bool> = (0..d).map(|_| rng.chance(0.5)).collect();
        let vs = random_vectors(rng, 20, d);
        for a in &vs {
            if dominates(a, a, &higher) {
                return Err("irreflexivity violated".into());
            }
        }
        for a in &vs {
            for b in &vs {
                if dominates(a, b, &higher) && dominates(b, a, &higher) {
                    return Err("antisymmetry violated".into());
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_front_members_are_mutually_nondominated() {
    forall(100, |rng| {
        let d = 2 + rng.below(3);
        let higher: Vec<bool> = (0..d).map(|_| rng.chance(0.5)).collect();
        let vs = random_vectors(rng, 40, d);
        let f = front(&vs, &higher);
        if f.is_empty() {
            return Err("empty front".into());
        }
        for &i in &f {
            for &j in &f {
                if i != j && dominates(&vs[i], &vs[j], &higher) {
                    return Err(format!("{i} dominates front member {j}"));
                }
            }
        }
        // every non-front point is dominated by someone
        for i in 0..vs.len() {
            if !f.contains(&i)
                && !vs.iter().any(|v| dominates(v, &vs[i], &higher))
            {
                return Err(format!("{i} excluded but undominated"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_nds_rank0_equals_front() {
    forall(60, |rng| {
        let higher = vec![rng.chance(0.5), rng.chance(0.5)];
        let vs = random_vectors(rng, 30, 2);
        let f = front(&vs, &higher);
        let ranks = non_dominated_sort(&vs, &higher);
        let rank0: Vec<usize> =
            (0..vs.len()).filter(|&i| ranks[i] == 0).collect();
        if f != rank0 {
            return Err(format!("front {f:?} != rank0 {rank0:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_summary_percentiles_monotone_and_bounded() {
    forall(150, |rng| {
        let n = 1 + rng.below(200);
        let xs: Vec<f64> = (0..n).map(|_| rng.normal_ms(10.0, 5.0)).collect();
        let s = Summary::of(&xs);
        let mut last = f64::NEG_INFINITY;
        for p in [0.0, 10.0, 25.0, 50.0, 75.0, 90.0, 99.0, 100.0] {
            let v = s.percentile(p);
            if v < last - 1e-12 {
                return Err(format!("percentile not monotone at {p}"));
            }
            if v < s.min - 1e-12 || v > s.max + 1e-12 {
                return Err("percentile out of [min,max]".into());
            }
            last = v;
        }
        if s.std < 0.0 {
            return Err("negative std".into());
        }
        Ok(())
    });
}

#[test]
fn prop_summary_scaling_is_linear() {
    forall(100, |rng| {
        let n = 2 + rng.below(100);
        let xs: Vec<f64> = (0..n).map(|_| rng.range(0.1, 100.0)).collect();
        let c = rng.range(0.1, 10.0);
        let s = Summary::of(&xs);
        let t = scale(&s, c);
        for (a, b) in [(t.mean, s.mean * c), (t.std, s.std * c), (t.max, s.max * c)] {
            if (a - b).abs() > 1e-6 * b.abs().max(1.0) {
                return Err(format!("scaling broke: {a} vs {b}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_contention_factor_monotone_superadditive() {
    for k in 0..8 {
        assert!(contention_factor(k + 1) > contention_factor(k));
        // bounded by perfect time slicing
        assert!(contention_factor(k) <= (k + 1) as f64);
    }
}

#[test]
fn prop_env_state_roundtrips_through_policy_codes() {
    let reg = Registry::paper();
    let p = carin::config::use_case("uc1", &reg, &profiles::galaxy_a71()).unwrap();
    let sol = rass::solve(&p);
    // iter_states must enumerate each state exactly once and design_for
    // must agree with the enumeration
    let states: Vec<(EnvState, usize)> = sol.policy.iter_states().collect();
    assert_eq!(states.len(), sol.policy.n_states());
    for (s, d) in &states {
        assert_eq!(sol.policy.design_for(*s), *d);
    }
    // distinct states (as (troubled-mask-over-device-engines, memory))
    let mut seen = std::collections::HashSet::new();
    for (s, _) in &states {
        let key = (s.troubled, s.memory);
        assert!(seen.insert(key), "duplicate state {key:?}");
    }
}

#[test]
fn prop_policy_never_dangles() {
    // for random subsets of devices/use-cases, every state maps to a
    // design index inside the design set
    let reg = Registry::paper();
    for dev in profiles::all() {
        for uc in carin::config::USE_CASES {
            let p = carin::config::use_case(uc, &reg, &dev).unwrap();
            let sol = rass::solve(&p);
            for (_, d) in sol.policy.iter_states() {
                assert!(d < sol.designs.len());
            }
        }
    }
}

#[test]
fn prop_constraint_violation_sign_consistent() {
    // violation() <= 0 iff satisfied(), on random constraints over a real
    // problem's metric sets
    let reg = Registry::paper();
    let p = carin::config::use_case("uc1", &reg, &profiles::galaxy_s20()).unwrap();
    forall(50, |rng| {
        let x = &p.space[rng.below(p.space.len())];
        let m = p.metrics(x);
        let metric = *rng.choose(&[
            Metric::Latency,
            Metric::Energy,
            Metric::MemFootprint,
            Metric::Accuracy,
        ]);
        let stat = *rng.choose(&[Statistic::Avg, Statistic::Max, Statistic::Min]);
        let bound = rng.range(0.0, 200.0);
        let c = carin::moo::Constraint { metric, stat, task: None, bound };
        let v = c.violation(&m);
        if (v <= 0.0) != c.satisfied(&m) {
            return Err(format!("sign mismatch v={v}"));
        }
        Ok(())
    });
}

#[test]
fn prop_simulator_latency_positive_under_any_state() {
    let reg = Registry::paper();
    forall(60, |rng| {
        let dev = profiles::all()[rng.below(3)].clone();
        let mut sim = carin::device::Simulator::new(dev.clone(), rng.next_u64());
        let engines = dev.engines.clone();
        let e = *rng.choose(&engines);
        sim.set_external_load(e, rng.f64());
        sim.set_temperature(e, rng.range(20.0, 120.0));
        sim.set_background_ram(rng.range(0.0, dev.ram_gb * 1e9));
        let proc = match e {
            carin::device::Engine::Cpu => Proc::Cpu { threads: 4, xnnpack: true },
            carin::device::Engine::Gpu => Proc::Gpu,
            carin::device::Engine::Npu => Proc::Npu,
            carin::device::Engine::Dsp => Proc::Dsp,
        };
        // only scheme-compatible pairs are ever enumerated by the space
        // builder; incompatible ones have no defined latency.
        let tasks: Vec<_> = reg
            .variants_for_task(carin::zoo::Task::ImageCls)
            .into_iter()
            .filter(|v| carin::device::compatible(&dev, proc, v.scheme))
            .collect();
        if tasks.is_empty() {
            return Ok(());
        }
        let v = tasks[rng.below(tasks.len())];
        let l = sim.sample_latency_ms(&reg, v, proc, rng.below(3));
        if !(l.is_finite() && l > 0.0) {
            return Err(format!("latency {l}"));
        }
        Ok(())
    });
}

#[test]
fn prop_batcher_conserves_requests() {
    use carin::coordinator::Batcher;
    use std::time::{Duration, Instant};
    forall(80, |rng| {
        let cap = 1 + rng.below(8);
        let n = rng.below(50);
        let mut b = Batcher::new(cap, 4, Duration::from_secs(100));
        let mut out = 0usize;
        for i in 0..n {
            let now = Instant::now();
            let r = carin::coordinator::batcher::Request {
                id: i as u64,
                payload: vec![0.0; 4].into(),
                enqueued: now,
                admitted: now,
                deadline: None,
            };
            let formed = b.push(r).map_err(|e| format!("push rejected: {e}"))?;
            out += formed.shed.len();
            if let Some(batch) = formed.batch {
                if batch.occupancy > cap {
                    return Err("batch over capacity".into());
                }
                out += batch.occupancy;
            }
        }
        let formed = b.flush();
        out += formed.shed.len();
        if let Some(batch) = formed.batch {
            out += batch.occupancy;
        }
        if out != n {
            return Err(format!("lost requests: {out} != {n}"));
        }
        Ok(())
    });
}

#[test]
fn prop_histogram_percentiles_track_summary() {
    // the telemetry histogram's bucketed percentiles must stay within
    // one geometric bucket width of the exact interpolated Summary
    // percentiles, for random dense sample sets on the latency scale
    use carin::telemetry::Histogram;
    let ratio = 10f64.powf(1.0 / 8.0); // latency_ms() bucket ratio
    forall(60, |rng| {
        let n = 200 + rng.below(800);
        let lo = rng.range(0.05, 5.0);
        let hi = lo * rng.range(4.0, 40.0);
        let samples: Vec<f64> = (0..n).map(|_| rng.range(lo, hi)).collect();
        let mut h = Histogram::latency_ms();
        for &s in &samples {
            h.observe(s);
        }
        let exact = Summary::of(&samples);
        for p in [50.0, 90.0, 99.0] {
            let (hp, ep) = (h.percentile(p), exact.percentile(p));
            // hp is a bucket upper bound: the exact value sits at most
            // one bucket below it; interpolation can nudge it at most
            // one bucket past in either direction.
            if !(ep <= hp * ratio && ep >= hp / (ratio * ratio)) {
                return Err(format!("p{p}: hist {hp} vs exact {ep}"));
            }
        }
        if h.count() != n as u64 {
            return Err(format!("count {} != {n}", h.count()));
        }
        Ok(())
    });
}

#[test]
fn prop_multi_metrics_bounds_hold_for_random_configs() {
    let reg = Registry::paper();
    let p = carin::config::use_case("uc3", &reg, &profiles::galaxy_s20()).unwrap();
    forall(100, |rng| {
        let x = &p.space[rng.below(p.space.len())];
        let m = p.metrics(x);
        let msz = m.tasks.len() as f64;
        if m.stp > msz + 1e-9 {
            return Err(format!("STP {} > M", m.stp));
        }
        if !(0.0..=1.0 + 1e-9).contains(&m.fairness) {
            return Err(format!("F {} out of range", m.fairness));
        }
        for t in &m.tasks {
            if t.ntt < 1.0 - 1e-12 {
                return Err(format!("NTT {} < 1", t.ntt));
            }
        }
        Ok(())
    });
}
