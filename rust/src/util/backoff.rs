//! Capped exponential backoff for supervised execution: the serving
//! coordinator retries transient inference/load failures with delays
//! `base * 2^attempt`, bounded by `cap`, so a glitching engine is given
//! room to recover without head-of-line-blocking the request queue.

use std::time::Duration;

/// Capped exponential backoff schedule. Deterministic (no jitter): the
/// serving loop is single-threaded per engine, so synchronized-retry
/// stampedes cannot occur and reproducibility wins.
#[derive(Debug, Clone)]
pub struct Backoff {
    base: Duration,
    cap: Duration,
    attempt: u32,
}

impl Backoff {
    pub fn new(base: Duration, cap: Duration) -> Backoff {
        Backoff { base, cap, attempt: 0 }
    }

    /// Delay before the next retry: `base * 2^n`, capped. Advances the
    /// attempt counter.
    pub fn next_delay(&mut self) -> Duration {
        // past 2^16 the cap has long since taken over; clamping the
        // exponent keeps the shift well-defined for pathological counts.
        let exp = self.attempt.min(16);
        self.attempt += 1;
        self.base.saturating_mul(1u32 << exp).min(self.cap)
    }

    /// Retries scheduled so far.
    pub fn attempts(&self) -> u32 {
        self.attempt
    }

    /// Restart the schedule (after a success).
    pub fn reset(&mut self) {
        self.attempt = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn doubles_until_cap() {
        let mut b = Backoff::new(Duration::from_millis(1), Duration::from_millis(8));
        assert_eq!(b.next_delay(), Duration::from_millis(1));
        assert_eq!(b.next_delay(), Duration::from_millis(2));
        assert_eq!(b.next_delay(), Duration::from_millis(4));
        assert_eq!(b.next_delay(), Duration::from_millis(8));
        // capped from here on
        assert_eq!(b.next_delay(), Duration::from_millis(8));
        assert_eq!(b.attempts(), 5);
    }

    #[test]
    fn reset_restarts_schedule() {
        let mut b = Backoff::new(Duration::from_millis(2), Duration::from_secs(1));
        b.next_delay();
        b.next_delay();
        b.reset();
        assert_eq!(b.next_delay(), Duration::from_millis(2));
    }

    #[test]
    fn huge_attempt_counts_stay_capped() {
        let mut b = Backoff::new(Duration::from_millis(1), Duration::from_millis(50));
        for _ in 0..100 {
            assert!(b.next_delay() <= Duration::from_millis(50));
        }
    }
}
