//! In-tree substrates for crates unavailable in the offline registry:
//! a fast deterministic RNG, descriptive statistics, capped exponential
//! backoff, and a minimal JSON parser (used for `artifacts/manifest.json`).

pub mod backoff;
pub mod json;
pub mod rng;
pub mod stats;

pub use backoff::Backoff;
pub use rng::Rng;
pub use stats::Summary;
