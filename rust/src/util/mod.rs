//! In-tree substrates for crates unavailable in the offline registry:
//! a fast deterministic RNG, descriptive statistics, capped exponential
//! backoff, a minimal JSON parser/writer (manifest loading, telemetry
//! export) and a leveled stderr logger (`CARIN_LOG`).

pub mod backoff;
pub mod json;
pub mod log;
pub mod rng;
pub mod stats;

pub use backoff::Backoff;
pub use rng::Rng;
pub use stats::Summary;
