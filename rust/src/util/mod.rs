//! In-tree substrates for crates unavailable in the offline registry:
//! a fast deterministic RNG, descriptive statistics, and a minimal JSON
//! parser (used for `artifacts/manifest.json`).

pub mod json;
pub mod rng;
pub mod stats;

pub use rng::Rng;
pub use stats::Summary;
