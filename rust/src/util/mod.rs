//! In-tree substrates for crates unavailable in the offline registry:
//! a fast deterministic RNG, descriptive statistics, capped exponential
//! backoff, a minimal JSON parser/writer (manifest loading, telemetry
//! export), a leveled stderr logger (`CARIN_LOG`) and the recycled
//! buffer pool backing the zero-copy serving hot path.

pub mod backoff;
pub mod bufpool;
pub mod json;
pub mod log;
pub mod rng;
pub mod stats;

pub use backoff::Backoff;
pub use bufpool::{BufPoolStats, BufferPool, TensorBuf};
pub use rng::Rng;
pub use stats::Summary;
