//! Tiny leveled logger (the `log`/`env_logger` crates are not in the
//! offline registry). Diagnostics go to stderr so benches and tests stay
//! machine-readable on stdout; the level comes from the `CARIN_LOG`
//! environment variable (`error|warn|info|debug|trace|off`, default
//! `warn`), so everything runs quiet unless explicitly asked not to.
//!
//! Use through the crate-root macros:
//!
//! ```no_run
//! carin::log_warn!("route {} went cold", "cnn_s_fp32");
//! carin::log_debug!("solved in {:?}", std::time::Duration::from_millis(3));
//! ```
//!
//! The enabled-check is a single relaxed atomic load, so disabled log
//! statements cost one branch on the hot path.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Log severity, most severe first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn name(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
            Level::Trace => "trace",
        }
    }

    /// Parse a level name (case-insensitive). `off`/`none` return `None`
    /// inside `Some` semantics handled by [`set_level`]; unknown strings
    /// are `Err`.
    pub fn parse(s: &str) -> Result<Option<Level>, ()> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Ok(Some(Level::Error)),
            "warn" | "warning" => Ok(Some(Level::Warn)),
            "info" => Ok(Some(Level::Info)),
            "debug" => Ok(Some(Level::Debug)),
            "trace" => Ok(Some(Level::Trace)),
            "off" | "none" => Ok(None),
            _ => Err(()),
        }
    }
}

/// Stored as `max enabled level + 1` (0 = everything off);
/// `UNSET` means "read `CARIN_LOG` on first use".
const UNSET: usize = usize::MAX;
static LEVEL: AtomicUsize = AtomicUsize::new(UNSET);

fn init_from_env() -> usize {
    let stored = match std::env::var("CARIN_LOG") {
        Ok(v) => match Level::parse(&v) {
            Ok(Some(l)) => l as usize + 1,
            Ok(None) => 0,
            Err(()) => Level::Warn as usize + 1,
        },
        Err(_) => Level::Warn as usize + 1,
    };
    LEVEL.store(stored, Ordering::Relaxed);
    stored
}

/// Override the level programmatically (`None` silences everything).
/// Wins over `CARIN_LOG` for the rest of the process.
pub fn set_level(level: Option<Level>) {
    LEVEL.store(level.map(|l| l as usize + 1).unwrap_or(0), Ordering::Relaxed);
}

/// The currently enabled maximum level, if any.
pub fn level() -> Option<Level> {
    match current() {
        0 => None,
        n => Some(match n - 1 {
            0 => Level::Error,
            1 => Level::Warn,
            2 => Level::Info,
            3 => Level::Debug,
            _ => Level::Trace,
        }),
    }
}

fn current() -> usize {
    let cur = LEVEL.load(Ordering::Relaxed);
    if cur == UNSET {
        init_from_env()
    } else {
        cur
    }
}

/// Whether a statement at `l` would be emitted.
#[inline]
pub fn enabled(l: Level) -> bool {
    (l as usize) < current()
}

/// Emit one record (used by the `log_*!` macros; call those instead).
pub fn log(l: Level, args: std::fmt::Arguments<'_>) {
    if enabled(l) {
        eprintln!("[carin {:5}] {}", l.name(), args);
    }
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Error, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_trace {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Trace, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_level_names() {
        assert_eq!(Level::parse("ERROR"), Ok(Some(Level::Error)));
        assert_eq!(Level::parse("warn"), Ok(Some(Level::Warn)));
        assert_eq!(Level::parse("Info"), Ok(Some(Level::Info)));
        assert_eq!(Level::parse("debug"), Ok(Some(Level::Debug)));
        assert_eq!(Level::parse("trace"), Ok(Some(Level::Trace)));
        assert_eq!(Level::parse("off"), Ok(None));
        assert_eq!(Level::parse("banana"), Err(()));
    }

    #[test]
    fn enabled_respects_ordering() {
        // tests share the process-wide level; restore what we found.
        let before = level();
        set_level(Some(Level::Info));
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        assert!(!enabled(Level::Trace));
        set_level(None);
        assert!(!enabled(Level::Error));
        set_level(before);
    }
}
