//! Deterministic pseudo-random number generation (xoshiro256++ seeded via
//! SplitMix64). The simulator, workload generators and property tests all
//! need reproducible randomness; the `rand` crate is not available in the
//! offline registry, so this is a self-contained implementation.

/// xoshiro256++ PRNG. Not cryptographic; plenty for simulation.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (SplitMix64 expansion).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform usize in [0, n). n must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Normal with given mean and standard deviation.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Log-normal multiplicative jitter centred on 1.0 — the shape of
    /// on-device latency noise (long right tail, never negative).
    pub fn jitter(&mut self, sigma: f64) -> f64 {
        (self.normal() * sigma).exp()
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn jitter_positive() {
        let mut r = Rng::new(4);
        for _ in 0..1000 {
            assert!(r.jitter(0.3) > 0.0);
        }
    }
}
