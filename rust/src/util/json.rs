//! Minimal JSON parser and writer — just enough to read
//! `artifacts/manifest.json` and to dump telemetry/trace exports
//! (objects, arrays, strings, numbers, booleans, null). serde is not
//! available in the offline registry.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Serialize to compact JSON text. Non-finite numbers (NaN, ±inf)
    /// have no JSON representation and are written as `null`, so dumps
    /// of metric vectors always re-parse.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(*x, out),
            Json::Str(s) => write_str(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_num(x: f64, out: &mut String) {
    use std::fmt::Write;
    if !x.is_finite() {
        out.push_str("null");
    } else if x == x.trunc() && x.abs() < 1e15 {
        let _ = write!(out, "{}", x as i64);
    } else {
        let _ = write!(out, "{x}");
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                use std::fmt::Write;
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u escape"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a run of plain bytes
                    let start = self.i;
                    while self.i < self.b.len()
                        && self.b[self.i] != b'"'
                        && self.b[self.i] != b'\\'
                    {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-')
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"[
            {"file": "cnn_s_fp32.hlo.txt", "params": 10044,
             "input": {"shape": [1, 96, 96, 3], "dtype": "float32"},
             "input_scale": null, "flops": 6.6e6,
             "weight_keys": ["a", "b"]}
        ]"#;
        let v = Json::parse(doc).unwrap();
        let e = &v.as_arr().unwrap()[0];
        assert_eq!(e.get("file").unwrap().as_str().unwrap(), "cnn_s_fp32.hlo.txt");
        assert_eq!(e.get("params").unwrap().as_usize().unwrap(), 10044);
        assert!(e.get("input_scale").unwrap().is_null());
        let shape: Vec<usize> = e
            .get("input").unwrap().get("shape").unwrap()
            .as_arr().unwrap().iter().map(|x| x.as_usize().unwrap()).collect();
        assert_eq!(shape, vec![1, 96, 96, 3]);
        assert_eq!(e.get("flops").unwrap().as_f64().unwrap(), 6.6e6);
    }

    #[test]
    fn escapes() {
        let v = Json::parse(r#""a\n\"bA""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\"bA");
    }

    #[test]
    fn rejects_trailing() {
        assert!(Json::parse("{} x").is_err());
    }

    #[test]
    fn nested() {
        let v = Json::parse(r#"{"a": [1, [2, {"b": true}]]}"#).unwrap();
        assert!(v.get("a").is_some());
    }

    #[test]
    fn dump_round_trips() {
        let doc = r#"{"a": [1, 2.5, -3], "b": {"c": "x\ny", "d": null}, "e": true}"#;
        let v = Json::parse(doc).unwrap();
        let dumped = v.dump();
        assert_eq!(Json::parse(&dumped).unwrap(), v);
        // integers stay integral, no float noise
        assert!(dumped.contains("[1,2.5,-3]"), "{dumped}");
    }

    #[test]
    fn dump_maps_non_finite_to_null() {
        assert_eq!(Json::Num(f64::NAN).dump(), "null");
        assert_eq!(Json::Num(f64::INFINITY).dump(), "null");
        assert_eq!(Json::Num(1.5).dump(), "1.5");
    }

    #[test]
    fn dump_escapes_strings() {
        let v = Json::Str("a\"b\\c\nd\u{1}".into());
        let dumped = v.dump();
        assert_eq!(Json::parse(&dumped).unwrap(), v);
    }

    #[test]
    fn negative_and_exponent_numbers() {
        let v = Json::parse("[-1.5e-3, 42]").unwrap();
        let a = v.as_arr().unwrap();
        assert!((a[0].as_f64().unwrap() + 0.0015).abs() < 1e-12);
        assert_eq!(a[1].as_usize().unwrap(), 42);
    }
}
