//! Descriptive statistics over profiling samples. The paper's narrow SLOs
//! bound min/max/avg/std/n-th-percentile values of a metric (§4.1), so a
//! single summary type carries all of them.

/// Summary statistics of a sample set (latency runs, energy draws, ...).
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    /// Sorted copy of the samples, kept for percentile queries.
    sorted: Vec<f64>,
}

impl Summary {
    /// The summary of zero samples: `n = 0` and every statistic 0.0.
    /// Reports use this for tasks that completed nothing, instead of
    /// fabricating a phantom `0.0` sample that would skew averages.
    pub fn empty() -> Self {
        Summary { n: 0, mean: 0.0, std: 0.0, min: 0.0, max: 0.0, sorted: Vec::new() }
    }

    /// Like [`Summary::of`] but maps an empty sample set to
    /// [`Summary::empty`] instead of panicking.
    pub fn of_or_empty(samples: &[f64]) -> Self {
        if samples.is_empty() {
            Summary::empty()
        } else {
            Summary::of(samples)
        }
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    pub fn of(samples: &[f64]) -> Self {
        assert!(!samples.is_empty(), "empty sample set");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            sorted,
        }
    }

    /// p-th percentile (0..=100), linear interpolation between ranks.
    /// Returns 0.0 for the empty summary.
    pub fn percentile(&self, p: f64) -> f64 {
        let p = p.clamp(0.0, 100.0);
        if self.n == 0 {
            return 0.0;
        }
        if self.n == 1 {
            return self.sorted[0];
        }
        let rank = p / 100.0 * (self.n - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = rank - lo as f64;
        self.sorted[lo] * (1.0 - frac) + self.sorted[hi] * frac
    }

    /// Coefficient of variation (std / mean).
    pub fn cv(&self) -> f64 {
        if self.mean == 0.0 { 0.0 } else { self.std / self.mean }
    }

    /// Multiply the whole distribution by `c > 0` (O(n), no re-sort:
    /// positive scaling preserves order). Used by the contention model.
    pub fn scaled(&self, c: f64) -> Summary {
        assert!(c > 0.0, "scale factor must be positive");
        Summary {
            n: self.n,
            mean: self.mean * c,
            std: self.std * c,
            min: self.min * c,
            max: self.max * c,
            sorted: self.sorted.iter().map(|x| x * c).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.mean, 2.5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.std - 1.118).abs() < 1e-3);
    }

    #[test]
    fn percentiles() {
        let s = Summary::of(&[10.0, 20.0, 30.0, 40.0, 50.0]);
        assert_eq!(s.percentile(0.0), 10.0);
        assert_eq!(s.percentile(50.0), 30.0);
        assert_eq!(s.percentile(100.0), 50.0);
        assert_eq!(s.percentile(25.0), 20.0);
    }

    #[test]
    fn single_sample() {
        let s = Summary::of(&[5.0]);
        assert_eq!(s.percentile(99.0), 5.0);
        assert_eq!(s.std, 0.0);
    }

    #[test]
    #[should_panic]
    fn empty_panics() {
        Summary::of(&[]);
    }

    #[test]
    fn empty_summary_is_all_zero() {
        let s = Summary::of_or_empty(&[]);
        assert!(s.is_empty());
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
        assert_eq!(s.percentile(95.0), 0.0);
        assert_eq!(s.cv(), 0.0);
    }

    #[test]
    fn of_or_empty_matches_of_when_nonempty() {
        let a = Summary::of_or_empty(&[1.0, 3.0]);
        let b = Summary::of(&[1.0, 3.0]);
        assert_eq!(a, b);
        assert!(!a.is_empty());
    }
}
