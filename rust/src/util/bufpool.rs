//! Recycled `f32` buffers for the zero-copy serving hot path.
//!
//! The serving loop's steady state must not touch the heap (see ROADMAP
//! "Memory path"): every formed batch and every stub/engine output needs
//! an owned `Vec<f32>`-shaped buffer, and allocating one per request is
//! exactly the framework overhead CARIn's responsiveness claims say to
//! eliminate. [`BufferPool`] keeps a small fixed set of slots, each an
//! `Arc<Vec<f32>>`, and *leases* them:
//!
//! - a **lease** finds a slot whose `Arc` strong count is 1 (nobody else
//!   holds it) and whose capacity already covers the requested length,
//!   mutates it in place through [`Arc::get_mut`] under the pool lock,
//!   and hands out a clone of the *existing* `Arc` — zero allocations on
//!   this path, in fully safe code;
//! - the handle is a [`TensorBuf`], a cheap-to-clone `Arc`-backed slice.
//!   Dropping the last outstanding clone **returns** the slot: the pool
//!   observes the strong count back at 1 on a later sweep and reuses the
//!   buffer. There is no drop glue to get wrong — return is a property
//!   of the refcount, not of a guard object;
//! - when no adequate slot is free the pool records a **miss**: it grows
//!   a free undersized slot, adds a new slot while under `max_slots`, or
//!   falls back to an unpooled one-shot buffer.
//!
//! Counters ([`BufferPool::stats`]) feed the
//! `carin_bufpool_{hits,misses,returns}` registry series; the serving
//! benches gate on a steady-state hit rate >= 0.95.

use std::ops::Deref;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Default slot cap: enough for every in-flight batch/output buffer of a
/// serving loop plus headroom, small enough to bound resident memory.
pub const DEFAULT_POOL_SLOTS: usize = 64;

/// An `Arc`-backed, immutable `f32` buffer.
///
/// This is the payload type of [`crate::runtime::Tensor::F32`] and of
/// `batcher::Request`/`Batch`: cloning bumps a refcount instead of deep
/// copying, so a sample can travel enqueue -> batch formation ->
/// watchdog channel -> engine without ever being duplicated. Buffers
/// leased from a [`BufferPool`] return to it automatically when the last
/// clone drops.
#[derive(Debug, Clone)]
pub struct TensorBuf(Arc<Vec<f32>>);

impl TensorBuf {
    pub fn as_slice(&self) -> &[f32] {
        &self.0
    }

    pub fn len(&self) -> usize {
        self.0.len()
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }
}

impl Deref for TensorBuf {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        &self.0
    }
}

impl From<Vec<f32>> for TensorBuf {
    fn from(v: Vec<f32>) -> Self {
        TensorBuf(Arc::new(v))
    }
}

impl PartialEq for TensorBuf {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

/// Cumulative pool counters (monotone; snapshot and diff per run).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct BufPoolStats {
    /// Leases served from a recycled slot without allocating.
    pub hits: u64,
    /// Leases that had to allocate (grow, new slot, or unpooled).
    pub misses: u64,
    /// Slots observed back at refcount 1 and made leasable again.
    pub returns: u64,
}

impl BufPoolStats {
    /// Hits as a fraction of all leases (0.0 when the pool is unused).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

struct Slots {
    bufs: Vec<Arc<Vec<f32>>>,
    /// `leased[i]` is set while slot `i` is handed out; cleared by the
    /// sweep once its strong count is back to 1.
    leased: Vec<bool>,
}

struct PoolShared {
    slots: Mutex<Slots>,
    max_slots: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    returns: AtomicU64,
}

/// A clonable handle to a shared pool of recyclable `f32` buffers. See
/// the module docs for the lease/return contract.
#[derive(Clone)]
pub struct BufferPool {
    shared: Arc<PoolShared>,
}

impl std::fmt::Debug for BufferPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        f.debug_struct("BufferPool")
            .field("max_slots", &self.shared.max_slots)
            .field("stats", &s)
            .finish()
    }
}

impl Default for BufferPool {
    fn default() -> Self {
        BufferPool::new(DEFAULT_POOL_SLOTS)
    }
}

impl BufferPool {
    /// A pool holding at most `max_slots` recycled buffers.
    pub fn new(max_slots: usize) -> BufferPool {
        BufferPool {
            shared: Arc::new(PoolShared {
                slots: Mutex::new(Slots { bufs: Vec::new(), leased: Vec::new() }),
                max_slots,
                hits: AtomicU64::new(0),
                misses: AtomicU64::new(0),
                returns: AtomicU64::new(0),
            }),
        }
    }

    /// A pool that never recycles: every lease is an unpooled miss.
    /// Used as the copy-path baseline in the memory-path benchmark.
    pub fn disabled() -> BufferPool {
        BufferPool::new(0)
    }

    /// Lease a buffer of exactly `len` elements. `fill` may push up to
    /// `len` elements into the (empty) buffer; the remainder is padded
    /// with `0.0`. On the steady-state hit path this performs zero heap
    /// allocations.
    pub fn lease_with(&self, len: usize, fill: impl FnOnce(&mut Vec<f32>)) -> TensorBuf {
        let mut slots = self.shared.slots.lock().unwrap();
        self.sweep_locked(&mut slots);

        // Best free slot: any with enough capacity is a hit; otherwise
        // remember the roomiest free one to grow (a miss, but it keeps
        // the slot count bounded).
        let mut fit: Option<usize> = None;
        let mut grow: Option<usize> = None;
        for i in 0..slots.bufs.len() {
            if slots.leased[i] || Arc::strong_count(&slots.bufs[i]) != 1 {
                continue;
            }
            let cap = slots.bufs[i].capacity();
            if cap >= len {
                fit = Some(i);
                break;
            }
            let roomier = match grow {
                None => true,
                Some(g) => cap > slots.bufs[g].capacity(),
            };
            if roomier {
                grow = Some(i);
            }
        }

        if let Some(i) = fit.or(grow) {
            let hit = fit.is_some();
            let buf = Arc::get_mut(&mut slots.bufs[i]).expect("free slot has refcount 1");
            buf.clear();
            fill(buf);
            buf.resize(len, 0.0);
            slots.leased[i] = true;
            let counter = if hit { &self.shared.hits } else { &self.shared.misses };
            counter.fetch_add(1, Ordering::Relaxed);
            return TensorBuf(slots.bufs[i].clone());
        }

        // No free slot at all: allocate, and keep it only while under
        // the cap so a burst cannot grow the pool without bound.
        self.shared.misses.fetch_add(1, Ordering::Relaxed);
        let mut v = Vec::with_capacity(len);
        fill(&mut v);
        v.resize(len, 0.0);
        let arc = Arc::new(v);
        if slots.bufs.len() < self.shared.max_slots {
            slots.bufs.push(arc.clone());
            slots.leased.push(true);
        }
        TensorBuf(arc)
    }

    /// Lease a zero-filled buffer of `len` elements.
    pub fn lease_zeroed(&self, len: usize) -> TensorBuf {
        self.lease_with(len, |_| {})
    }

    /// Observe dropped leases now instead of waiting for the next
    /// lease's sweep; call before reading final [`BufferPool::stats`].
    pub fn sweep_returns(&self) {
        let mut slots = self.shared.slots.lock().unwrap();
        self.sweep_locked(&mut slots);
    }

    fn sweep_locked(&self, slots: &mut Slots) {
        for i in 0..slots.bufs.len() {
            if slots.leased[i] && Arc::strong_count(&slots.bufs[i]) == 1 {
                slots.leased[i] = false;
                self.shared.returns.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    pub fn stats(&self) -> BufPoolStats {
        BufPoolStats {
            hits: self.shared.hits.load(Ordering::Relaxed),
            misses: self.shared.misses.load(Ordering::Relaxed),
            returns: self.shared.returns.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_lease_misses_then_reuse_hits() {
        let pool = BufferPool::new(4);
        let a = pool.lease_with(8, |v| v.extend_from_slice(&[1.0, 2.0]));
        assert_eq!(&a[..2], &[1.0, 2.0]);
        assert_eq!(a.len(), 8);
        assert_eq!(a[7], 0.0, "padded with zeros");
        assert_eq!(pool.stats(), BufPoolStats { hits: 0, misses: 1, returns: 0 });

        let ptr = a.as_slice().as_ptr();
        drop(a);
        let b = pool.lease_zeroed(8);
        assert!(std::ptr::eq(ptr, b.as_slice().as_ptr()), "slot recycled");
        assert!(b.iter().all(|&x| x == 0.0), "stale contents cleared");
        let s = pool.stats();
        assert_eq!((s.hits, s.misses, s.returns), (1, 1, 1));
    }

    #[test]
    fn concurrent_leases_get_distinct_buffers() {
        let pool = BufferPool::new(4);
        let a = pool.lease_zeroed(4);
        let b = pool.lease_zeroed(4);
        assert!(!std::ptr::eq(a.as_slice().as_ptr(), b.as_slice().as_ptr()));
        assert_eq!(pool.stats().misses, 2);
    }

    #[test]
    fn clone_keeps_slot_leased_until_last_drop() {
        let pool = BufferPool::new(4);
        let a = pool.lease_zeroed(4);
        let a2 = a.clone();
        drop(a);
        pool.sweep_returns();
        assert_eq!(pool.stats().returns, 0, "a clone is still live");
        drop(a2);
        pool.sweep_returns();
        assert_eq!(pool.stats().returns, 1);
    }

    #[test]
    fn disabled_pool_never_recycles() {
        let pool = BufferPool::disabled();
        let a = pool.lease_zeroed(4);
        drop(a);
        let _b = pool.lease_zeroed(4);
        let s = pool.stats();
        assert_eq!(s.hits, 0);
        assert_eq!(s.misses, 2);
    }

    #[test]
    fn smaller_lease_reuses_bigger_slot_without_allocating() {
        let pool = BufferPool::new(4);
        let a = pool.lease_zeroed(64);
        drop(a);
        let b = pool.lease_with(16, |v| v.push(7.0));
        assert_eq!(b.len(), 16);
        assert_eq!(b[0], 7.0);
        assert_eq!(pool.stats().hits, 1);
    }

    #[test]
    fn slot_cap_bounds_pool_growth() {
        let pool = BufferPool::new(2);
        let held: Vec<_> = (0..5).map(|_| pool.lease_zeroed(4)).collect();
        drop(held);
        pool.sweep_returns();
        // only the two retained slots can come back
        assert_eq!(pool.stats().returns, 2);
    }
}
