//! Exporters: JSON-lines event dumps (one event object per line, replay
//! order) and Prometheus text-format metric snapshots. Export runs off
//! the request path — it allocates freely.

use std::collections::BTreeMap;
use std::fmt::Write;

use crate::util::json::Json;

use super::event::{Event, EventKind};
use super::metrics::Registry;

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

fn num(x: f64) -> Json {
    Json::Num(x)
}

/// One event as a JSON object (`{"seq":..,"t_ns":..,"event":..,...}`).
pub fn event_to_json(ev: &Event) -> Json {
    let mut pairs: Vec<(&str, Json)> = vec![
        ("seq", num(ev.seq as f64)),
        ("t_ns", num(ev.t_ns as f64)),
        ("event", Json::Str(ev.kind.name().to_string())),
    ];
    match ev.kind {
        EventKind::Admitted { task, id }
        | EventKind::Batched { task, id }
        | EventKind::Shed { task, id }
        | EventKind::Failed { task, id } => {
            pairs.push(("task", num(task as f64)));
            pairs.push(("id", num(id as f64)));
        }
        EventKind::TimedOut { task, id, deadline_ns } => {
            pairs.push(("task", num(task as f64)));
            pairs.push(("id", num(id as f64)));
            pairs.push(("deadline_ns", num(deadline_ns as f64)));
        }
        EventKind::Dispatched { task, route, occupancy } => {
            pairs.push(("task", num(task as f64)));
            pairs.push(("route", num(route as f64)));
            pairs.push(("occupancy", num(occupancy as f64)));
        }
        EventKind::Retried { task, attempts } => {
            pairs.push(("task", num(task as f64)));
            pairs.push(("attempts", num(attempts as f64)));
        }
        EventKind::Completed {
            task,
            id,
            queue_ns,
            batch_ns,
            exec_ns,
            total_ns,
            deadline_met,
        } => {
            pairs.push(("task", num(task as f64)));
            pairs.push(("id", num(id as f64)));
            pairs.push(("queue_ns", num(queue_ns as f64)));
            pairs.push(("batch_ns", num(batch_ns as f64)));
            pairs.push(("exec_ns", num(exec_ns as f64)));
            pairs.push(("total_ns", num(total_ns as f64)));
            pairs.push(("deadline_met", Json::Bool(deadline_met)));
        }
        EventKind::FaultRaised { engine, task } => {
            pairs.push(("engine", num(engine as f64)));
            pairs.push(("task", num(task as f64)));
        }
        EventKind::FaultCleared { engine } => {
            pairs.push(("engine", num(engine as f64)));
        }
        EventKind::Probe { engine, ok } => {
            pairs.push(("engine", num(engine as f64)));
            pairs.push(("ok", Json::Bool(ok)));
        }
        EventKind::Switch {
            from,
            to,
            troubled,
            faulted,
            memory,
            bad_mask,
            decision_ns,
            fallback,
        } => {
            pairs.push(("from", num(from as f64)));
            pairs.push(("to", num(to as f64)));
            pairs.push(("troubled", num(troubled as f64)));
            pairs.push(("faulted", num(faulted as f64)));
            pairs.push(("memory", Json::Bool(memory)));
            pairs.push(("bad_mask", num(bad_mask as f64)));
            pairs.push(("decision_ns", num(decision_ns as f64)));
            pairs.push(("fallback", Json::Bool(fallback)));
        }
    }
    obj(pairs)
}

/// JSON-lines dump: one event object per line, oldest first. Each line
/// parses standalone, so the timeline can be streamed, grepped and
/// replayed without a JSON-array reader.
pub fn events_jsonl(events: &[Event]) -> String {
    let mut out = String::new();
    for ev in events {
        out.push_str(&event_to_json(ev).dump());
        out.push('\n');
    }
    out
}

/// Prometheus text-format snapshot of a registry: counters, gauges and
/// histograms with cumulative `_bucket{le=..}` series, `_sum` and
/// `_count`, deterministic order. Metric names may embed a label set
/// (`name{k="v"}`); the `# TYPE` header uses the base name and is
/// emitted once per family.
pub fn prometheus_snapshot(reg: &Registry) -> String {
    let mut out = String::new();
    let mut last_family = String::new();
    let mut type_line = |out: &mut String, name: &str, kind: &str, last: &mut String| {
        let base = name.split('{').next().unwrap_or(name);
        if base != last {
            let _ = writeln!(out, "# TYPE {base} {kind}");
            *last = base.to_string();
        }
    };

    for (name, v) in reg.counters() {
        type_line(&mut out, name, "counter", &mut last_family);
        let _ = writeln!(out, "{name} {v}");
    }
    for (name, v) in reg.gauges() {
        type_line(&mut out, name, "gauge", &mut last_family);
        let _ = writeln!(out, "{name} {v}");
    }
    for (name, h) in reg.histograms() {
        type_line(&mut out, name, "histogram", &mut last_family);
        let mut cum = 0u64;
        for (i, &bound) in h.bounds().iter().enumerate() {
            cum += h.counts()[i];
            let _ = writeln!(out, "{name}_bucket{{le=\"{bound}\"}} {cum}");
        }
        cum += h.counts().last().copied().unwrap_or(0);
        let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {cum}");
        let _ = writeln!(out, "{name}_sum {}", h.sum());
        let _ = writeln!(out, "{name}_count {}", h.count());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::Recorder;

    #[test]
    fn jsonl_lines_parse_standalone() {
        let mut r = Recorder::new(16);
        r.record(EventKind::Admitted { task: 0, id: 1 });
        r.record(EventKind::Dispatched { task: 0, route: 3, occupancy: 1 });
        r.record(EventKind::Switch {
            from: 0,
            to: 2,
            troubled: 0,
            faulted: 1,
            memory: false,
            bad_mask: 1,
            decision_ns: 120,
            fallback: true,
        });
        let dump = events_jsonl(&r.events());
        let lines: Vec<&str> = dump.lines().collect();
        assert_eq!(lines.len(), 3);
        for line in &lines {
            let v = Json::parse(line).expect("valid json line");
            assert!(v.get("event").is_some());
            assert!(v.get("t_ns").is_some());
        }
        let disp = Json::parse(lines[1]).unwrap();
        assert_eq!(disp.get("route").unwrap().as_usize().unwrap(), 3);
        let sw = Json::parse(lines[2]).unwrap();
        assert_eq!(sw.get("event").unwrap().as_str().unwrap(), "switch");
        assert_eq!(sw.get("bad_mask").unwrap().as_usize().unwrap(), 1);
        assert_eq!(sw.get("fallback"), Some(&Json::Bool(true)));
    }

    #[test]
    fn prometheus_counters_gauges_and_histogram_shape() {
        let mut reg = Registry::new();
        reg.add("carin_requests_total", 5);
        reg.set_gauge("carin_current_design", 1.0);
        reg.observe("carin_exec_latency_ms", 0.5);
        reg.observe("carin_exec_latency_ms", 2.0);
        let text = prometheus_snapshot(&reg);
        assert!(text.contains("# TYPE carin_requests_total counter"));
        assert!(text.contains("carin_requests_total 5"));
        assert!(text.contains("# TYPE carin_current_design gauge"));
        assert!(text.contains("# TYPE carin_exec_latency_ms histogram"));
        assert!(text.contains("carin_exec_latency_ms_bucket{le=\"+Inf\"} 2"));
        assert!(text.contains("carin_exec_latency_ms_count 2"));
        assert!(text.contains("carin_exec_latency_ms_sum 2.5"));
        // buckets are cumulative: last bucket equals count
        let inf: u64 = text
            .lines()
            .find(|l| l.contains("le=\"+Inf\""))
            .and_then(|l| l.split_whitespace().last())
            .and_then(|v| v.parse().ok())
            .unwrap();
        assert_eq!(inf, 2);
    }

    #[test]
    fn prometheus_labeled_series_share_one_type_line() {
        let mut reg = Registry::new();
        reg.add("carin_task_completed_total{task=\"0\"}", 3);
        reg.add("carin_task_completed_total{task=\"1\"}", 4);
        let text = prometheus_snapshot(&reg);
        let type_lines =
            text.lines().filter(|l| l.starts_with("# TYPE carin_task_completed_total")).count();
        assert_eq!(type_lines, 1, "{text}");
        assert!(text.contains("carin_task_completed_total{task=\"0\"} 3"));
        assert!(text.contains("carin_task_completed_total{task=\"1\"} 4"));
    }
}
