//! Telemetry: the measurement substrate of the serving stack.
//!
//! CARIn's headline claim is *responsiveness* — the Runtime Manager
//! reacts to environmental fluctuation through a pre-computed switching
//! table in near-zero time (§4.3, Figures 7–8). Validating that claim
//! (and every perf PR after it) needs more than end-of-run aggregates:
//! this module turns the serving path into an inspectable system with a
//! replayable event timeline, per-request spans and exportable metrics,
//! at a cost small enough to leave on in production runs.
//!
//! # Event taxonomy
//!
//! [`EventKind`] covers the request lifecycle and the supervision loop:
//!
//! | event | meaning |
//! |---|---|
//! | `admitted` | request dequeued from the arrival channel |
//! | `batched` | request parked in a dynamic batcher |
//! | `dispatched` | engine call issued (occupancy = batch size) |
//! | `retried` | engine call needed > 1 attempt |
//! | `shed` | request dropped at dequeue (deadline unreachable) |
//! | `failed` | retries exhausted |
//! | `timed_out` | retries exhausted with the final attempt abandoned by the watchdog deadline |
//! | `completed` | request done, with queue/batch/exec/total span ns |
//! | `fault_raised` | consecutive failures crossed the fault threshold |
//! | `probe` | off-path health probe of a faulted route |
//! | `fault_cleared` | probes healed the route |
//! | `switch` | RM design switch: state, `bad_mask`, from/to, decision ns |
//!
//! The `switch` events double as the RASS **audit trail**: every policy
//! lookup that changed the design records the exact [`EnvState`] bits it
//! saw and how long the lookup took, so adaptation traces can be
//! replayed against the fault schedule that caused them.
//!
//! # Overhead budget
//!
//! Recording must never perturb what it measures:
//!
//! * the [`Recorder`] ring buffer is allocated once at construction and
//!   overwrites oldest-first when full — recording is O(1), allocation-
//!   free, and events are `Copy` (no strings on the hot path);
//! * [`Histogram::observe`] is a binary search over ~57 fixed buckets;
//! * [`Registry`] counter/gauge updates are a `BTreeMap` lookup that
//!   allocates only the first time a name is seen;
//! * exporters ([`export::events_jsonl`], [`export::prometheus_snapshot`])
//!   are off the request path entirely.
//!
//! Size the recorder to the run (default 8192 events ≈ 2k requests'
//! full lifecycle): a wrapped buffer still exports, but the replayable
//! window starts at the oldest retained event and
//! [`Recorder::dropped`] reports what was lost.
//!
//! # Sharding
//!
//! The pooled coordinator gives every worker thread its own `Telemetry`
//! shard (so hot-path recording stays lock-free and O(1)) constructed
//! via [`Telemetry::with_epoch`] from one shared epoch. At report time
//! [`Telemetry::merge_shards`] reduces the shards: events re-sort by
//! timestamp with globally monotone sequence numbers, counters add,
//! histograms merge bucket-wise, and the serving window spans the
//! earliest admission to the latest completion across all shards.
//!
//! [`EnvState`]: crate::moo::rass::EnvState

pub mod event;
pub mod export;
pub mod metrics;
pub mod span;

pub use event::{Event, EventKind, Recorder};
pub use metrics::{Histogram, Registry};
pub use span::Span;

/// Default ring-buffer capacity (events) for a serving run.
pub const DEFAULT_EVENT_CAPACITY: usize = 8192;

/// The per-coordinator telemetry bundle: the event recorder, the metric
/// registry and the serving-window bounds (first admission → last
/// completion) used for setup-free throughput accounting.
#[derive(Debug)]
pub struct Telemetry {
    pub recorder: Recorder,
    pub registry: Registry,
    first_admit_ns: Option<u64>,
    last_done_ns: Option<u64>,
}

impl Telemetry {
    pub fn new(event_capacity: usize) -> Telemetry {
        Telemetry::with_epoch(event_capacity, std::time::Instant::now())
    }

    /// A telemetry bundle measuring time from an explicit epoch. The
    /// pooled coordinator hands every worker shard the same epoch so the
    /// shards' event timestamps are directly comparable at merge time.
    pub fn with_epoch(event_capacity: usize, epoch: std::time::Instant) -> Telemetry {
        Telemetry {
            recorder: Recorder::with_epoch(event_capacity, epoch),
            registry: Registry::new(),
            first_admit_ns: None,
            last_done_ns: None,
        }
    }

    /// Reduce per-worker telemetry shards (all sharing `epoch`) into one
    /// bundle: events are concatenated and re-recorded in timestamp order
    /// (sequence numbers are reassigned globally monotone), registries
    /// merge per [`Registry::merge_from`], and the serving window spans
    /// the earliest admission to the latest completion across shards.
    pub fn merge_shards(epoch: std::time::Instant, shards: Vec<Telemetry>) -> Telemetry {
        let cap: usize = shards
            .iter()
            .map(|s| s.recorder.capacity())
            .sum::<usize>()
            .max(1);
        let mut merged = Telemetry::with_epoch(cap, epoch);
        let mut events: Vec<Event> = Vec::new();
        for shard in &shards {
            events.extend(shard.recorder.events());
            merged.registry.merge_from(&shard.registry);
            if let Some(a) = shard.first_admit_ns {
                merged.first_admit_ns =
                    Some(merged.first_admit_ns.map_or(a, |m: u64| m.min(a)));
            }
            if let Some(b) = shard.last_done_ns {
                merged.last_done_ns = Some(merged.last_done_ns.map_or(b, |m: u64| m.max(b)));
            }
        }
        events.sort_by_key(|e| e.t_ns);
        for e in events {
            merged.recorder.record_at(e.t_ns, e.kind);
        }
        merged
    }

    /// Forget the serving window (call at the start of a run; events and
    /// metrics accumulate across runs, the window does not).
    pub fn reset_window(&mut self) {
        self.first_admit_ns = None;
        self.last_done_ns = None;
    }

    /// Note an admission at the current instant (first one opens the
    /// serving window).
    pub fn note_admit(&mut self) {
        let t = self.recorder.now_ns();
        if self.first_admit_ns.is_none() {
            self.first_admit_ns = Some(t);
        }
        self.last_done_ns = Some(self.last_done_ns.unwrap_or(t).max(t));
    }

    /// Note a completion at the current instant (extends the window).
    pub fn note_done(&mut self) {
        let t = self.recorder.now_ns();
        self.last_done_ns = Some(self.last_done_ns.unwrap_or(t).max(t));
    }

    /// Window bounds in ns since the recorder epoch, if any request was
    /// admitted.
    pub fn window_ns(&self) -> Option<(u64, u64)> {
        match (self.first_admit_ns, self.last_done_ns) {
            (Some(a), Some(b)) => Some((a, b.max(a))),
            _ => None,
        }
    }

    /// Serving-window length in seconds (first admission to last
    /// completion), if any request was admitted.
    pub fn window_s(&self) -> Option<f64> {
        self.window_ns().map(|(a, b)| (b - a) as f64 / 1e9)
    }

    /// JSON-lines dump of the retained event timeline.
    pub fn events_jsonl(&self) -> String {
        export::events_jsonl(&self.recorder.events())
    }

    /// Prometheus text-format snapshot of the registry.
    pub fn prometheus(&self) -> String {
        export::prometheus_snapshot(&self.registry)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_tracks_admit_to_done() {
        let mut t = Telemetry::new(16);
        assert!(t.window_s().is_none());
        t.note_admit();
        std::thread::sleep(std::time::Duration::from_millis(2));
        t.note_done();
        let w = t.window_s().unwrap();
        assert!(w >= 0.002, "window {w}");
        t.reset_window();
        assert!(t.window_s().is_none());
    }

    #[test]
    fn merge_shards_orders_events_and_spans_window() {
        let epoch = std::time::Instant::now();
        let mut a = Telemetry::with_epoch(8, epoch);
        let mut b = Telemetry::with_epoch(8, epoch);
        a.recorder.record_at(10, EventKind::Admitted { task: 0, id: 0 });
        b.recorder.record_at(5, EventKind::Admitted { task: 1, id: 1 });
        a.registry.inc("c");
        b.registry.add("c", 2);
        a.first_admit_ns = Some(10);
        a.last_done_ns = Some(20);
        b.first_admit_ns = Some(5);
        b.last_done_ns = Some(15);
        let m = Telemetry::merge_shards(epoch, vec![a, b]);
        let evs = m.recorder.events();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].t_ns, 5);
        assert_eq!(evs[1].t_ns, 10);
        assert_eq!((evs[0].seq, evs[1].seq), (0, 1));
        assert_eq!(m.registry.counter("c"), 3);
        assert_eq!(m.window_ns(), Some((5, 20)));
    }

    #[test]
    fn bundle_exports_are_consistent() {
        let mut t = Telemetry::new(16);
        t.recorder.record(EventKind::Admitted { task: 0, id: 0 });
        t.registry.inc("carin_requests_admitted_total");
        assert_eq!(t.events_jsonl().lines().count(), 1);
        assert!(t.prometheus().contains("carin_requests_admitted_total 1"));
    }
}
