//! Fixed-bucket log-scale latency histograms, counters and gauges, held
//! in a name-indexed [`Registry`].
//!
//! Histograms trade exactness for a bounded footprint: bucket bounds are
//! geometric (a fixed ratio apart), so a percentile query is accurate to
//! one bucket width — a bounded *relative* error at every magnitude.
//! The agreement with exact [`crate::util::Summary`] percentiles is
//! property-tested in `tests/prop_invariants.rs`. Observation is O(log
//! #buckets) (a binary search) and never allocates.

use std::collections::BTreeMap;

/// Log-scale fixed-bucket histogram. Bucket `i` covers
/// `(bounds[i-1], bounds[i]]`; values above the last bound land in an
/// implicit overflow bucket.
#[derive(Debug, Clone)]
pub struct Histogram {
    /// Ascending bucket upper bounds.
    bounds: Vec<f64>,
    /// Per-bucket counts; `counts[bounds.len()]` is the overflow bucket.
    counts: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Histogram {
    /// Geometric bounds from `lo` to at least `hi`, `per_decade` buckets
    /// per factor of 10.
    pub fn log_scale(lo: f64, hi: f64, per_decade: usize) -> Histogram {
        assert!(lo > 0.0 && hi > lo && per_decade > 0, "bad histogram scale");
        let ratio = 10f64.powf(1.0 / per_decade as f64);
        let mut bounds = Vec::new();
        let mut b = lo;
        while b < hi * (1.0 + 1e-12) {
            bounds.push(b);
            b *= ratio;
        }
        let n = bounds.len();
        Histogram {
            bounds,
            counts: vec![0; n + 1],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// The default latency scale: 1 µs to 10 s (in ms), 8 buckets per
    /// decade (~33% relative bucket width), 57 buckets.
    pub fn latency_ms() -> Histogram {
        Histogram::log_scale(1e-3, 1e4, 8)
    }

    /// Record one observation. O(log #buckets), no allocation.
    pub fn observe(&mut self, v: f64) {
        if !v.is_finite() {
            return;
        }
        let i = self.bounds.partition_point(|&b| b < v);
        self.counts[i] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.sum / self.count as f64 }
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.max }
    }

    /// Bucket upper bounds (the overflow bucket has none).
    pub fn bounds(&self) -> &[f64] {
        &self.bounds
    }

    /// Per-bucket counts, overflow last (`len == bounds().len() + 1`).
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// The bucket index a value falls into.
    pub fn bucket_index(&self, v: f64) -> usize {
        self.bounds.partition_point(|&b| b < v)
    }

    /// `(lower, upper)` bounds of bucket `i` (`upper` is `+inf` for the
    /// overflow bucket, `lower` is 0 for the first).
    pub fn bucket_bounds(&self, i: usize) -> (f64, f64) {
        let lo = if i == 0 { 0.0 } else { self.bounds[i - 1] };
        let hi = self.bounds.get(i).copied().unwrap_or(f64::INFINITY);
        (lo, hi)
    }

    /// Fold another histogram into this one. Identical bucket layouts
    /// merge exactly (element-wise count addition); mismatched layouts
    /// fall back to re-observing each foreign bucket at its midpoint —
    /// accurate to one bucket width, same as any percentile query.
    pub fn merge(&mut self, other: &Histogram) {
        if other.count == 0 {
            return;
        }
        if self.bounds == other.bounds {
            for (c, &o) in self.counts.iter_mut().zip(other.counts.iter()) {
                *c += o;
            }
            self.count += other.count;
            self.sum += other.sum;
            self.min = self.min.min(other.min);
            self.max = self.max.max(other.max);
        } else {
            let sum_before = self.sum;
            for (i, &c) in other.counts.iter().enumerate() {
                if c == 0 {
                    continue;
                }
                let (lo, hi) = other.bucket_bounds(i);
                let mid = if hi.is_finite() { (lo + hi) / 2.0 } else { other.max };
                for _ in 0..c {
                    self.observe(mid);
                }
            }
            // midpoint re-observation approximates bucket placement only;
            // the moments are carried over exactly
            self.sum = sum_before + other.sum;
            self.min = self.min.min(other.min);
            self.max = self.max.max(other.max);
        }
    }

    /// p-th percentile estimate (0..=100): the upper bound of the bucket
    /// holding the nearest-rank observation, clamped into the observed
    /// `[min, max]`. Accurate to one bucket width; 0.0 when empty.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let p = p.clamp(0.0, 100.0);
        let target = ((p / 100.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                let hi = self.bounds.get(i).copied().unwrap_or(self.max);
                return hi.clamp(self.min, self.max);
            }
        }
        self.max
    }
}

/// Name-indexed metrics: monotonic counters, point-in-time gauges and
/// histograms. `BTreeMap` keys keep exports deterministic. Lookups of
/// existing metrics never allocate; a name allocates once on first use.
#[derive(Debug, Default)]
pub struct Registry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    histograms: BTreeMap<String, Histogram>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Increment a counter by 1.
    pub fn inc(&mut self, name: &str) {
        self.add(name, 1);
    }

    /// Increment a counter by `by`.
    pub fn add(&mut self, name: &str, by: u64) {
        match self.counters.get_mut(name) {
            Some(v) => *v += by,
            None => {
                self.counters.insert(name.to_string(), by);
            }
        }
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn set_gauge(&mut self, name: &str, v: f64) {
        match self.gauges.get_mut(name) {
            Some(g) => *g = v,
            None => {
                self.gauges.insert(name.to_string(), v);
            }
        }
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    /// Record into a histogram, creating it on the default latency scale
    /// on first use.
    pub fn observe(&mut self, name: &str, v: f64) {
        match self.histograms.get_mut(name) {
            Some(h) => h.observe(v),
            None => {
                let mut h = Histogram::latency_ms();
                h.observe(v);
                self.histograms.insert(name.to_string(), h);
            }
        }
    }

    /// Install a histogram with explicit buckets (before first observe).
    pub fn register_histogram(&mut self, name: &str, h: Histogram) {
        self.histograms.insert(name.to_string(), h);
    }

    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        self.histograms.get(name)
    }

    pub fn counters(&self) -> impl Iterator<Item = (&str, u64)> {
        self.counters.iter().map(|(k, &v)| (k.as_str(), v))
    }

    pub fn gauges(&self) -> impl Iterator<Item = (&str, f64)> {
        self.gauges.iter().map(|(k, &v)| (k.as_str(), v))
    }

    pub fn histograms(&self) -> impl Iterator<Item = (&str, &Histogram)> {
        self.histograms.iter().map(|(k, v)| (k.as_str(), v))
    }

    /// Fold another registry into this one: counters add, gauges take the
    /// other's value (last write wins), histograms merge per name. Used to
    /// reduce per-worker telemetry shards into one report-time registry.
    pub fn merge_from(&mut self, other: &Registry) {
        for (name, v) in other.counters() {
            self.add(name, v);
        }
        for (name, v) in other.gauges() {
            self.set_gauge(name, v);
        }
        for (name, h) in other.histograms() {
            match self.histograms.get_mut(name) {
                Some(mine) => mine.merge(h),
                None => {
                    self.histograms.insert(name.to_string(), h.clone());
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Summary;

    #[test]
    fn buckets_are_geometric_and_cover_range() {
        let h = Histogram::log_scale(1.0, 100.0, 4);
        let r = 10f64.powf(0.25);
        for w in h.bounds().windows(2) {
            assert!((w[1] / w[0] - r).abs() < 1e-9);
        }
        assert_eq!(h.bounds()[0], 1.0);
        assert!(*h.bounds().last().unwrap() >= 100.0);
        assert_eq!(h.counts().len(), h.bounds().len() + 1);
    }

    #[test]
    fn observe_counts_and_moments() {
        let mut h = Histogram::latency_ms();
        for v in [0.5, 1.0, 2.0, 4.0] {
            h.observe(v);
        }
        assert_eq!(h.count(), 4);
        assert!((h.sum() - 7.5).abs() < 1e-12);
        assert!((h.mean() - 1.875).abs() < 1e-12);
        assert_eq!(h.min(), 0.5);
        assert_eq!(h.max(), 4.0);
        assert_eq!(h.counts().iter().sum::<u64>(), 4);
    }

    #[test]
    fn non_finite_observations_are_ignored() {
        let mut h = Histogram::latency_ms();
        h.observe(f64::NAN);
        h.observe(f64::INFINITY);
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile(99.0), 0.0);
    }

    #[test]
    fn overflow_and_underflow_buckets() {
        let mut h = Histogram::log_scale(1.0, 10.0, 1);
        h.observe(0.01); // below lo -> first bucket
        h.observe(1e9); // above hi -> overflow
        assert_eq!(h.counts()[0], 1);
        assert_eq!(*h.counts().last().unwrap(), 1);
        // percentile of the overflow bucket reports the observed max
        assert_eq!(h.percentile(100.0), 1e9);
    }

    #[test]
    fn percentile_within_one_bucket_of_exact() {
        let mut h = Histogram::latency_ms();
        let samples: Vec<f64> = (1..=1000).map(|i| i as f64 / 10.0).collect();
        for &s in &samples {
            h.observe(s);
        }
        let exact = Summary::of(&samples);
        let ratio = 10f64.powf(1.0 / 8.0);
        for p in [10.0, 50.0, 90.0, 99.0] {
            let (hp, ep) = (h.percentile(p), exact.percentile(p));
            assert!(
                ep <= hp * ratio && ep >= hp / (ratio * ratio),
                "p{p}: hist {hp} exact {ep}"
            );
        }
    }

    #[test]
    fn merge_identical_layouts_is_exact() {
        let mut a = Histogram::latency_ms();
        let mut b = Histogram::latency_ms();
        for v in [1.0, 2.0] {
            a.observe(v);
        }
        for v in [4.0, 8.0] {
            b.observe(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), 4);
        assert!((a.sum() - 15.0).abs() < 1e-12);
        assert_eq!(a.min(), 1.0);
        assert_eq!(a.max(), 8.0);
        assert_eq!(a.counts().iter().sum::<u64>(), 4);
    }

    #[test]
    fn merge_mismatched_layouts_keeps_moments() {
        let mut a = Histogram::latency_ms();
        let mut b = Histogram::log_scale(1.0, 10.0, 1);
        a.observe(1.0);
        b.observe(3.0);
        b.observe(5.0);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert!((a.sum() - 9.0).abs() < 1e-12);
        assert_eq!(a.min(), 1.0);
        assert_eq!(a.max(), 5.0);
    }

    #[test]
    fn registry_merge_from_shards() {
        let mut a = Registry::new();
        let mut b = Registry::new();
        a.inc("reqs");
        b.add("reqs", 2);
        b.inc("only_b");
        a.set_gauge("g", 1.0);
        b.set_gauge("g", 5.0);
        a.observe("lat", 1.0);
        b.observe("lat", 2.0);
        b.observe("lat2", 3.0);
        a.merge_from(&b);
        assert_eq!(a.counter("reqs"), 3);
        assert_eq!(a.counter("only_b"), 1);
        assert_eq!(a.gauge("g"), Some(5.0));
        assert_eq!(a.histogram("lat").unwrap().count(), 2);
        assert_eq!(a.histogram("lat2").unwrap().count(), 1);
    }

    #[test]
    fn registry_counters_gauges_histograms() {
        let mut r = Registry::new();
        r.inc("reqs");
        r.add("reqs", 2);
        assert_eq!(r.counter("reqs"), 3);
        assert_eq!(r.counter("nope"), 0);
        r.set_gauge("design", 1.0);
        r.set_gauge("design", 2.0);
        assert_eq!(r.gauge("design"), Some(2.0));
        r.observe("lat_ms", 1.5);
        r.observe("lat_ms", 3.0);
        assert_eq!(r.histogram("lat_ms").unwrap().count(), 2);
        // deterministic iteration order (BTreeMap)
        let names: Vec<&str> = r.counters().map(|(k, _)| k).collect();
        assert_eq!(names, vec!["reqs"]);
    }
}
