//! Per-request spans: the four timestamps a request passes on its way
//! through the serving pipeline, and the queue/batch/execute/total
//! breakdown derived from them.

use std::time::{Duration, Instant};

use super::event::EventKind;
use super::Recorder;

/// The lifecycle timestamps of one request.
///
/// ```text
/// submitted ──queue──▶ admitted ──batch──▶ dispatched ──exec──▶ completed
/// └──────────────────────────── total ───────────────────────────┘
/// ```
///
/// * `queue` — arrival-channel wait (submission to dequeue);
/// * `batch` — dynamic-batcher wait (zero on the unbatched path);
/// * `exec`  — engine call including supervised retries and backoff;
/// * `total` — request-to-response (the e2e latency of the report).
#[derive(Debug, Clone, Copy)]
pub struct Span {
    pub task: usize,
    pub id: u64,
    pub submitted: Instant,
    pub admitted: Instant,
    pub dispatched: Instant,
    pub completed: Instant,
}

impl Span {
    pub fn queue_ms(&self) -> f64 {
        ms(self.submitted, self.admitted)
    }

    pub fn batch_ms(&self) -> f64 {
        ms(self.admitted, self.dispatched)
    }

    pub fn exec_ms(&self) -> f64 {
        ms(self.dispatched, self.completed)
    }

    pub fn total_ms(&self) -> f64 {
        ms(self.submitted, self.completed)
    }

    /// The [`EventKind::Completed`] record of this span, with durations
    /// in integer nanoseconds.
    pub fn completed_kind(&self, deadline_met: bool) -> EventKind {
        EventKind::Completed {
            task: self.task as u32,
            id: self.id,
            queue_ns: ns(self.submitted, self.admitted),
            batch_ns: ns(self.admitted, self.dispatched),
            exec_ns: ns(self.dispatched, self.completed),
            total_ns: ns(self.submitted, self.completed),
            deadline_met,
        }
    }

    /// Record this span's completion event, stamped at `completed`.
    pub fn record(&self, rec: &mut Recorder, deadline_met: bool) {
        let t = rec.ns_of(self.completed);
        rec.record_at(t, self.completed_kind(deadline_met));
    }

    /// The [`EventKind::TimedOut`] record of this span: the request's
    /// final engine attempt was abandoned by the watchdog `deadline`
    /// after dispatch (`completed` marks when the deadline fired, so
    /// the queue/batch phases stay comparable with completed spans).
    pub fn timed_out_kind(&self, deadline: Duration) -> EventKind {
        EventKind::TimedOut {
            task: self.task as u32,
            id: self.id,
            deadline_ns: deadline.as_nanos() as u64,
        }
    }

    /// Record this span's timeout event, stamped at `completed`.
    pub fn record_timeout(&self, rec: &mut Recorder, deadline: Duration) {
        let t = rec.ns_of(self.completed);
        rec.record_at(t, self.timed_out_kind(deadline));
    }
}

fn ms(from: Instant, to: Instant) -> f64 {
    to.saturating_duration_since(from).as_secs_f64() * 1000.0
}

fn ns(from: Instant, to: Instant) -> u64 {
    to.saturating_duration_since(from).as_nanos() as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn breakdown_sums_to_total() {
        let t0 = Instant::now();
        let s = Span {
            task: 2,
            id: 7,
            submitted: t0,
            admitted: t0 + Duration::from_millis(3),
            dispatched: t0 + Duration::from_millis(5),
            completed: t0 + Duration::from_millis(9),
        };
        assert!((s.queue_ms() - 3.0).abs() < 1e-9);
        assert!((s.batch_ms() - 2.0).abs() < 1e-9);
        assert!((s.exec_ms() - 4.0).abs() < 1e-9);
        assert!((s.total_ms() - 9.0).abs() < 1e-9);
        assert!(
            (s.queue_ms() + s.batch_ms() + s.exec_ms() - s.total_ms()).abs() < 1e-9
        );
    }

    #[test]
    fn completed_kind_carries_breakdown() {
        let t0 = Instant::now();
        let s = Span {
            task: 1,
            id: 42,
            submitted: t0,
            admitted: t0 + Duration::from_micros(10),
            dispatched: t0 + Duration::from_micros(10),
            completed: t0 + Duration::from_micros(30),
        };
        match s.completed_kind(true) {
            EventKind::Completed { task, id, batch_ns, total_ns, deadline_met, .. } => {
                assert_eq!(task, 1);
                assert_eq!(id, 42);
                assert_eq!(batch_ns, 0); // unbatched: admitted == dispatched
                assert_eq!(total_ns, 30_000);
                assert!(deadline_met);
            }
            other => panic!("wrong kind {other:?}"),
        }
    }

    #[test]
    fn out_of_order_instants_saturate() {
        let t0 = Instant::now();
        let s = Span {
            task: 0,
            id: 0,
            submitted: t0 + Duration::from_millis(5),
            admitted: t0,
            dispatched: t0,
            completed: t0,
        };
        assert_eq!(s.queue_ms(), 0.0);
        assert_eq!(s.total_ms(), 0.0);
    }
}
