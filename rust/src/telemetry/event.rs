//! Typed structured events and the bounded ring-buffer [`Recorder`].
//!
//! Every event is `Copy` — no strings, no heap — so recording one is a
//! timestamp read plus an array store. The buffer is allocated once at
//! construction; when full, the oldest events are overwritten (and
//! counted in [`Recorder::dropped`]), so the recorder never allocates on
//! the serving hot path.

use std::time::Instant;

/// The event taxonomy of the serving path. Request-lifecycle events
/// carry the task index and request id; supervision events carry engine
/// indices ([`crate::device::Engine::index`]) and the environment bits
/// the decision saw.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EventKind {
    /// Request dequeued from the arrival channel into the serve loop.
    Admitted { task: u32, id: u64 },
    /// Request parked in a dynamic batcher awaiting batch formation.
    Batched { task: u32, id: u64 },
    /// Engine call issued for a request or a formed batch. `route` is
    /// the interned [`crate::runtime::ArtifactId`] value — resolve it to
    /// a display stem through the coordinator's route table at export
    /// time; the event itself stays string-free.
    Dispatched { task: u32, route: u32, occupancy: u32 },
    /// An engine call succeeded only after `attempts` tries.
    Retried { task: u32, attempts: u32 },
    /// Request shed at dequeue: its deadline was unreachable.
    Shed { task: u32, id: u64 },
    /// Request failed after retries were exhausted.
    Failed { task: u32, id: u64 },
    /// Request abandoned after retries were exhausted and the final
    /// attempt exceeded its watchdog deadline (the hung executor thread
    /// was abandoned; `deadline_ns` is the per-call bound that fired).
    TimedOut { task: u32, id: u64, deadline_ns: u64 },
    /// Request finished, with its span breakdown (`queue` = channel
    /// wait, `batch` = batcher wait, `exec` = engine time incl. retries).
    Completed {
        task: u32,
        id: u64,
        queue_ns: u64,
        batch_ns: u64,
        exec_ns: u64,
        total_ns: u64,
        deadline_met: bool,
    },
    /// Consecutive failures crossed the threshold: the engine carrying
    /// `task`'s route was reported faulted to the monitor.
    FaultRaised { engine: u8, task: u32 },
    /// Health probes healed the engine; the raw fault signal cleared.
    FaultCleared { engine: u8 },
    /// One off-path health probe of a faulted route.
    Probe { engine: u8, ok: bool },
    /// The Runtime Manager switched design (the audit-trail record: the
    /// environment state seen, its `bad_mask`, prior and chosen design,
    /// and the policy-lookup time).
    Switch {
        from: u32,
        to: u32,
        troubled: u8,
        faulted: u8,
        memory: bool,
        bad_mask: u8,
        decision_ns: u64,
        /// Taken while a signal was raised (fallback) vs. after all
        /// signals cleared (recovery).
        fallback: bool,
    },
}

impl EventKind {
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::Admitted { .. } => "admitted",
            EventKind::Batched { .. } => "batched",
            EventKind::Dispatched { .. } => "dispatched",
            EventKind::Retried { .. } => "retried",
            EventKind::Shed { .. } => "shed",
            EventKind::Failed { .. } => "failed",
            EventKind::TimedOut { .. } => "timed_out",
            EventKind::Completed { .. } => "completed",
            EventKind::FaultRaised { .. } => "fault_raised",
            EventKind::FaultCleared { .. } => "fault_cleared",
            EventKind::Probe { .. } => "probe",
            EventKind::Switch { .. } => "switch",
        }
    }
}

/// One recorded event: a monotonic timestamp (ns since the recorder's
/// epoch), a global sequence number and the typed payload.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Event {
    pub seq: u64,
    pub t_ns: u64,
    pub kind: EventKind,
}

/// Bounded ring-buffer event recorder. O(1) recording, zero allocation
/// after construction; `events()` returns the retained window oldest
/// first.
#[derive(Debug)]
pub struct Recorder {
    epoch: Instant,
    buf: Vec<Event>,
    cap: usize,
    /// Next write position once the buffer has wrapped.
    next: usize,
    seq: u64,
    dropped: u64,
}

impl Recorder {
    pub fn new(capacity: usize) -> Recorder {
        Recorder::with_epoch(capacity, Instant::now())
    }

    /// A recorder whose timestamps are measured from an explicit epoch.
    /// Per-worker shard recorders of one serving run share a single epoch
    /// so their `t_ns` values are directly comparable at merge time.
    pub fn with_epoch(capacity: usize, epoch: Instant) -> Recorder {
        assert!(capacity > 0, "recorder capacity must be positive");
        Recorder {
            epoch,
            buf: Vec::with_capacity(capacity),
            cap: capacity,
            next: 0,
            seq: 0,
            dropped: 0,
        }
    }

    /// The instant timestamps are measured from.
    pub fn epoch(&self) -> Instant {
        self.epoch
    }

    /// Monotonic ns since the epoch.
    #[inline]
    pub fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// Ns-since-epoch of an [`Instant`] (0 if it predates the epoch).
    #[inline]
    pub fn ns_of(&self, t: Instant) -> u64 {
        t.saturating_duration_since(self.epoch).as_nanos() as u64
    }

    /// Record an event stamped with the current time.
    #[inline]
    pub fn record(&mut self, kind: EventKind) {
        let t = self.now_ns();
        self.record_at(t, kind);
    }

    /// Record an event with an explicit timestamp (ns since epoch).
    pub fn record_at(&mut self, t_ns: u64, kind: EventKind) {
        let ev = Event { seq: self.seq, t_ns, kind };
        self.seq += 1;
        if self.buf.len() < self.cap {
            // within the pre-reserved capacity: push never reallocates
            self.buf.push(ev);
        } else {
            self.buf[self.next] = ev;
            self.next = (self.next + 1) % self.cap;
            self.dropped += 1;
        }
    }

    /// Number of retained events (≤ capacity).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Events overwritten because the buffer wrapped.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Total events ever recorded (retained + dropped).
    pub fn recorded(&self) -> u64 {
        self.seq
    }

    /// The retained events, oldest first (chronological / seq order).
    pub fn events(&self) -> Vec<Event> {
        if self.buf.len() < self.cap || self.next == 0 {
            self.buf.clone()
        } else {
            let mut out = Vec::with_capacity(self.cap);
            out.extend_from_slice(&self.buf[self.next..]);
            out.extend_from_slice(&self.buf[..self.next]);
            out
        }
    }

    /// Drop every retained event (capacity and epoch are kept).
    pub fn clear(&mut self) {
        self.buf.clear();
        self.next = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(r: &Recorder) -> Vec<u64> {
        r.events()
            .iter()
            .map(|e| match e.kind {
                EventKind::Admitted { id, .. } => id,
                _ => u64::MAX,
            })
            .collect()
    }

    #[test]
    fn records_in_order_under_capacity() {
        let mut r = Recorder::new(8);
        for id in 0..5u64 {
            r.record(EventKind::Admitted { task: 0, id });
        }
        assert_eq!(r.len(), 5);
        assert_eq!(r.dropped(), 0);
        assert_eq!(kinds(&r), vec![0, 1, 2, 3, 4]);
        let evs = r.events();
        for w in evs.windows(2) {
            assert!(w[0].seq < w[1].seq);
            assert!(w[0].t_ns <= w[1].t_ns);
        }
    }

    #[test]
    fn ring_overwrites_oldest_and_counts_drops() {
        let mut r = Recorder::new(4);
        for id in 0..10u64 {
            r.record(EventKind::Admitted { task: 0, id });
        }
        assert_eq!(r.len(), 4);
        assert_eq!(r.capacity(), 4);
        assert_eq!(r.dropped(), 6);
        assert_eq!(r.recorded(), 10);
        // oldest-first window of the most recent 4
        assert_eq!(kinds(&r), vec![6, 7, 8, 9]);
    }

    #[test]
    fn ring_never_grows_past_capacity() {
        let mut r = Recorder::new(16);
        let before = r.buf.capacity();
        for id in 0..1000u64 {
            r.record(EventKind::Admitted { task: 1, id });
        }
        assert_eq!(r.buf.capacity(), before, "ring buffer reallocated");
        assert_eq!(r.len(), 16);
    }

    #[test]
    fn clear_keeps_capacity() {
        let mut r = Recorder::new(4);
        for id in 0..6u64 {
            r.record(EventKind::Admitted { task: 0, id });
        }
        r.clear();
        assert!(r.is_empty());
        r.record(EventKind::Probe { engine: 0, ok: true });
        assert_eq!(r.len(), 1);
        assert_eq!(r.events()[0].kind.name(), "probe");
    }

    #[test]
    fn ns_of_saturates_before_epoch() {
        let r = Recorder::new(1);
        let past = r.epoch(); // identical instant -> 0, never panics
        assert_eq!(r.ns_of(past), 0);
    }
}
