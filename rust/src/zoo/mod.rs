//! Model zoo registry: the paper's model suites (Tables 2–5) plus the
//! mapping onto the executable JAX/Pallas artifacts built by
//! `python/compile/aot.py`.
//!
//! Two tiers (DESIGN.md §6):
//! * **registry models** — every model the paper evaluates, with its
//!   published FLOPs / parameter counts / per-scheme accuracies, so the
//!   MOO problems CARIn solves here are the paper's exact decision
//!   problems;
//! * **executable stand-ins** — each registry model references the
//!   artifact of a compact zoo model of the same family and scale class,
//!   which the PJRT runtime actually loads and runs on the request path.

pub mod registry;

pub use registry::{ModelEntry, Registry, Task};

/// Post-training quantisation schemes (paper Table 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Scheme {
    Fp32,
    Fp16,
    Dr8,
    Fx8,
    Ffx8,
}

impl Scheme {
    pub const ALL: [Scheme; 5] =
        [Scheme::Fp32, Scheme::Fp16, Scheme::Dr8, Scheme::Fx8, Scheme::Ffx8];

    /// Weight bytes per parameter (Table 1: FP16 halves, int8 quarters).
    pub fn bytes_per_param(self) -> f64 {
        match self {
            Scheme::Fp32 => 4.0,
            Scheme::Fp16 => 2.0,
            Scheme::Dr8 | Scheme::Fx8 | Scheme::Ffx8 => 1.0,
        }
    }

    /// True for the schemes whose compute path is integer-dominant.
    pub fn is_integer(self) -> bool {
        matches!(self, Scheme::Dr8 | Scheme::Fx8 | Scheme::Ffx8)
    }

    pub fn name(self) -> &'static str {
        match self {
            Scheme::Fp32 => "fp32",
            Scheme::Fp16 => "fp16",
            Scheme::Dr8 => "dr8",
            Scheme::Fx8 => "fx8",
            Scheme::Ffx8 => "ffx8",
        }
    }

    pub fn from_name(s: &str) -> Option<Scheme> {
        Scheme::ALL.iter().copied().find(|x| x.name() == s)
    }

    pub fn index(self) -> usize {
        match self {
            Scheme::Fp32 => 0,
            Scheme::Fp16 => 1,
            Scheme::Dr8 => 2,
            Scheme::Fx8 => 3,
            Scheme::Ffx8 => 4,
        }
    }
}

/// A concrete (model, scheme) pair — one row of the model repository.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Variant {
    /// Index into [`Registry::models`].
    pub model: usize,
    pub scheme: Scheme,
}

impl Variant {
    /// Stored model file size in bytes.
    pub fn size_bytes(&self, reg: &Registry) -> f64 {
        let m = &reg.models[self.model];
        m.mparams * 1e6 * self.scheme.bytes_per_param()
    }

    /// Computational workload in FLOPs (scheme-independent).
    pub fn flops(&self, reg: &Registry) -> f64 {
        reg.models[self.model].gflops * 1e9
    }

    /// Task accuracy of this variant, if the scheme exists for the model.
    pub fn accuracy(&self, reg: &Registry) -> Option<f64> {
        reg.models[self.model].accuracy[self.scheme.index()]
    }

    pub fn describe(&self, reg: &Registry) -> String {
        format!(
            "{} {}",
            reg.models[self.model].name,
            self.scheme.name().to_uppercase()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheme_size_factors() {
        assert_eq!(Scheme::Fp32.bytes_per_param(), 4.0);
        assert_eq!(Scheme::Fp16.bytes_per_param(), 2.0);
        assert_eq!(Scheme::Ffx8.bytes_per_param(), 1.0);
    }

    #[test]
    fn scheme_roundtrip_names() {
        for s in Scheme::ALL {
            assert_eq!(Scheme::from_name(s.name()), Some(s));
        }
        assert_eq!(Scheme::from_name("bogus"), None);
    }

    #[test]
    fn variant_size() {
        let reg = Registry::paper();
        let mnv2 = reg.find("MobileNet V2 1.0").unwrap();
        let v = Variant { model: mnv2, scheme: Scheme::Fp32 };
        // 3.49 M params * 4 B
        assert!((v.size_bytes(&reg) - 13.96e6).abs() < 1e4);
        let v8 = Variant { model: mnv2, scheme: Scheme::Dr8 };
        assert!((v.size_bytes(&reg) / v8.size_bytes(&reg) - 4.0).abs() < 1e-9);
    }
}
