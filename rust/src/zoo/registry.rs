//! The paper's model suites, transcribed from Tables 2–5.
//!
//! Accuracy cells that the ACM/arXiv source renders illegibly (parts of
//! Tables 3 and 5) are filled with values consistent with the paper's
//! prose and marked `estimated: true`; they sit between the published
//! neighbours and preserve every ordering the evaluation relies on.
//! UC4's age model reports mean-absolute-error (lower-better); it is
//! stored as the higher-better quality `100 - MAE` so a single accuracy
//! direction serves all tasks (documented in DESIGN.md §6).

use super::Scheme;

/// DL task identifiers used by the four use cases (§6.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Task {
    /// UC1: image classification on ImageNet-1k.
    ImageCls,
    /// UC2: text classification (emotions).
    TextCls,
    /// UC3 task 1: scene classification (MIT Indoor Scenes).
    SceneCls,
    /// UC3 task 2: audio event classification (AudioSet).
    AudioCls,
    /// UC4: gender / age / ethnicity estimation on UTKFace.
    FaceGender,
    FaceAge,
    FaceEth,
}

impl Task {
    pub fn name(self) -> &'static str {
        match self {
            Task::ImageCls => "image-classification",
            Task::TextCls => "text-classification",
            Task::SceneCls => "scene-classification",
            Task::AudioCls => "audio-classification",
            Task::FaceGender => "face-gender",
            Task::FaceAge => "face-age",
            Task::FaceEth => "face-ethnicity",
        }
    }
}

/// Architecture family — drives the per-engine execution profile of the
/// device simulator (transformers vectorise worse on NPUs/DSPs, etc.).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Family {
    Cnn,
    Transformer,
    Audio,
}

/// One registry model (a row of Tables 2–5).
#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub name: &'static str,
    pub family: Family,
    pub task: Task,
    /// Input edge (pixels), sequence length (tokens) or samples (audio).
    pub input_size: usize,
    /// Published workload in GFLOPs.
    pub gflops: f64,
    /// Published parameter count in millions.
    pub mparams: f64,
    /// Accuracy per scheme [fp32, fp16, dr8, fx8, ffx8]; `None` where the
    /// paper publishes no variant (e.g. MobileViT int8, YAMNet fx8/ffx8).
    pub accuracy: [Option<f64>; 5],
    /// Batch size used at inference (UC4 uses 4).
    pub batch: usize,
    /// Executable stand-in: artifact stem produced by `compile/aot.py`.
    pub artifact: &'static str,
    /// True where an illegible table cell was reconstructed (see module doc).
    pub estimated: bool,
}

/// The model repository: every model of Tables 2–5.
#[derive(Debug, Clone)]
pub struct Registry {
    pub models: Vec<ModelEntry>,
}

const fn acc5(a: f64, b: f64, c: f64, d: f64, e: f64) -> [Option<f64>; 5] {
    [Some(a), Some(b), Some(c), Some(d), Some(e)]
}

const fn acc2(a: f64, b: f64) -> [Option<f64>; 5] {
    [Some(a), Some(b), None, None, None]
}

const fn acc3(a: f64, b: f64, c: f64) -> [Option<f64>; 5] {
    [Some(a), Some(b), Some(c), None, None]
}

impl Registry {
    /// The paper's full model suite.
    pub fn paper() -> Registry {
        use Family::*;
        use Task::*;
        let m = |name, family, task, input_size, gflops, mparams, accuracy,
                 batch, artifact, estimated| ModelEntry {
            name, family, task, input_size, gflops, mparams, accuracy,
            batch, artifact, estimated,
        };
        Registry {
            models: vec![
                // ---- Table 2: UC1, image classification on ImageNet-1k ----
                m("MobileNet V2 1.0", Cnn, ImageCls, 224, 0.60, 3.49,
                  acc5(71.92, 71.96, 71.65, 71.28, 71.26), 1, "cnn_s", false),
                m("RegNetY 008", Cnn, ImageCls, 224, 1.60, 6.25,
                  acc5(74.28, 74.28, 74.18, 74.45, 74.47), 1, "cnn_m", false),
                m("MobileViT XS", Transformer, ImageCls, 256, 2.10, 2.31,
                  acc2(74.61, 74.61), 1, "vit_xs", false),
                m("EfficientNet Lite0", Cnn, ImageCls, 224, 0.77, 4.63,
                  acc5(75.19, 75.23, 75.14, 75.09, 75.11), 1, "cnn_m", false),
                m("MobileNet V2 1.4", Cnn, ImageCls, 224, 1.16, 6.09,
                  acc5(75.66, 75.68, 75.47, 75.41, 75.45), 1, "cnn_m", false),
                m("RegNetY 016", Cnn, ImageCls, 224, 3.23, 11.18,
                  acc5(76.76, 76.76, 76.62, 76.92, 76.84), 1, "cnn_l", false),
                m("MobileViT S", Transformer, ImageCls, 256, 4.06, 5.57,
                  acc2(78.31, 78.30), 1, "vit_xs", false),
                m("EfficientNet Lite4", Cnn, ImageCls, 300, 5.11, 12.95,
                  acc5(80.81, 80.80, 80.78, 80.69, 80.71), 1, "cnn_l", false),
                // ---- Table 3: UC2, text classification on Emotions ----
                // (accuracy cells partially illegible in the source; the
                // legible anchors are XtremeDistil fp16 = 93.30 and
                // MobileBERT fp16 = 93.80.)
                m("BERT-L2-H128", Transformer, TextCls, 64, 0.05, 4.4,
                  acc5(91.45, 91.45, 91.30, 91.10, 91.05), 1, "bert_s", true),
                m("XtremeDistil-L6-H256", Transformer, TextCls, 64, 0.63, 12.8,
                  acc5(93.35, 93.30, 93.20, 93.05, 93.00), 1, "bert_m", true),
                m("MobileBERT-L24-H512", Transformer, TextCls, 64, 2.66, 25.3,
                  acc5(93.85, 93.80, 93.65, 93.50, 93.45), 1, "bert_l", true),
                // ---- Table 4: UC3, scene + audio classification ----
                m("EfficientNet Lite0 (scene)", Cnn, SceneCls, 224, 0.59, 3.44,
                  acc5(69.78, 69.70, 68.96, 69.18, 69.18), 1, "scene_s", false),
                m("EfficientNet Lite2 (scene)", Cnn, SceneCls, 260, 1.51, 4.87,
                  acc5(76.72, 76.72, 77.16, 77.69, 77.54), 1, "scene_m", false),
                m("EfficientNet Lite4 (scene)", Cnn, SceneCls, 300, 4.57, 11.76,
                  acc5(79.33, 79.33, 79.18, 79.78, 79.48), 1, "scene_l", false),
                // YAMNet mAP is stored x100 to share the accuracy scale.
                m("YAMNet", Audio, AudioCls, 15600, 0.14, 3.75,
                  acc3(37.56, 37.57, 36.20), 1, "yamnet_lite", false),
                // ---- Table 5: UC4, facial attribute prediction ----
                // (gender row legible; age/ethnicity cells reconstructed.
                // Age quality = 100 - MAE.)
                m("GenderNet-MNV2", Cnn, FaceGender, 62, 0.04, 0.66,
                  acc5(95.12, 94.95, 94.90, 94.79, 94.90), 4, "face_gender", false),
                m("AgeNet-MNV2", Cnn, FaceAge, 62, 0.04, 0.66,
                  acc5(94.65, 94.63, 94.58, 94.52, 94.55), 4, "face_age", true),
                m("EthniNet-MNV2", Cnn, FaceEth, 62, 0.04, 0.66,
                  acc5(80.21, 80.18, 80.02, 79.85, 79.92), 4, "face_eth", true),
            ],
        }
    }

    pub fn find(&self, name: &str) -> Option<usize> {
        self.models.iter().position(|m| m.name == name)
    }

    /// All models for a given task.
    pub fn for_task(&self, task: Task) -> Vec<usize> {
        (0..self.models.len())
            .filter(|&i| self.models[i].task == task)
            .collect()
    }

    /// All valid variants (model x scheme with published accuracy) of a task.
    pub fn variants_for_task(&self, task: Task) -> Vec<super::Variant> {
        let mut out = Vec::new();
        for i in self.for_task(task) {
            for s in Scheme::ALL {
                if self.models[i].accuracy[s.index()].is_some() {
                    out.push(super::Variant { model: i, scheme: s });
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_counts_match_tables() {
        let reg = Registry::paper();
        assert_eq!(reg.for_task(Task::ImageCls).len(), 8); // Table 2
        assert_eq!(reg.for_task(Task::TextCls).len(), 3); // Table 3
        assert_eq!(reg.for_task(Task::SceneCls).len(), 3); // Table 4 (vision)
        assert_eq!(reg.for_task(Task::AudioCls).len(), 1); // Table 4 (audio)
        assert_eq!(reg.for_task(Task::FaceGender).len(), 1); // Table 5
    }

    #[test]
    fn mobilevit_has_no_int8_variants() {
        let reg = Registry::paper();
        for name in ["MobileViT XS", "MobileViT S"] {
            let i = reg.find(name).unwrap();
            assert!(reg.models[i].accuracy[Scheme::Dr8.index()].is_none());
            assert!(reg.models[i].accuracy[Scheme::Ffx8.index()].is_none());
        }
    }

    #[test]
    fn yamnet_schemes_match_table4() {
        let reg = Registry::paper();
        let i = reg.find("YAMNet").unwrap();
        assert!(reg.models[i].accuracy[Scheme::Dr8.index()].is_some());
        assert!(reg.models[i].accuracy[Scheme::Fx8.index()].is_none());
    }

    #[test]
    fn uc1_variant_count() {
        let reg = Registry::paper();
        // 6 models x 5 schemes + 2 MobileViT x 2 schemes = 34
        assert_eq!(reg.variants_for_task(Task::ImageCls).len(), 34);
    }

    #[test]
    fn uc4_batch_is_4() {
        let reg = Registry::paper();
        for t in [Task::FaceGender, Task::FaceAge, Task::FaceEth] {
            for i in reg.for_task(t) {
                assert_eq!(reg.models[i].batch, 4);
            }
        }
    }

    #[test]
    fn every_model_has_artifact_standin() {
        let reg = Registry::paper();
        for m in &reg.models {
            assert!(!m.artifact.is_empty());
        }
    }
}
