//! carin — CLI launcher.
//!
//! Subcommands (hand-rolled parser; clap is not in the offline registry):
//!
//! ```text
//! carin solve   --uc uc1 --device s20       # designs + switching policy (Tables 7/8)
//! carin eval    --uc uc1 [--summary]        # figure rows (Figs 3-6) + takeaway ratios
//! carin trace   --uc uc1 --device s20       # runtime-adaptation trace (Figs 7/8)
//! carin serve   --uc uc1 --device s20 -n 96 # real PJRT serving over artifacts/
//! carin zoo     [--uc uc1]                  # model registry dump (Tables 2-5)
//! carin devices                             # device profiles (Table 6)
//! carin storage                             # Table 10
//! carin solvetime                           # Table 9
//! ```
//!
//! `trace --json <path>` writes the adaptation trace as JSON;
//! `serve --telemetry <path>` dumps the event timeline as JSON-lines to
//! `<path>` plus a Prometheus metric snapshot to `<path>.prom`;
//! `serve --pooled` serves through the per-engine worker pool
//! (one engine-owning thread per policy engine) instead of the
//! single-loop coordinator. Both flavours are built through
//! [`ServeOptions`] and served behind the [`Coordinator`] trait;
//! `serve --slo <ms>` tracks a latency SLO and arms the watchdog
//! (engine calls are abandoned after `SLO × timeout-mult`, tunable with
//! `serve --timeout-mult <x>`, default 8).
//! Diagnostics go to stderr through the `CARIN_LOG` leveled logger
//! (`--log <level>` overrides the environment).

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use carin::config;
use carin::coordinator::{run_trace, Coordinator, ServeOptions};
use carin::device::profiles;
use carin::harness::{self, figures, tables};
use carin::manager::EventSchedule;
use carin::moo::rass;
use carin::runtime::load_manifest;
use carin::workload;
use carin::zoo::{Registry, Scheme};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        usage();
        return;
    }
    let cmd = args[0].clone();
    let opts = parse_opts(&args[1..]);
    if let Some(l) = opts.get("log") {
        match carin::util::log::Level::parse(l) {
            Ok(level) => carin::util::log::set_level(level),
            Err(()) => {
                eprintln!("error: unknown log level {l} (error|warn|info|debug|trace|off)");
                std::process::exit(1);
            }
        }
    }
    let result = match cmd.as_str() {
        "solve" => cmd_solve(&opts),
        "eval" => cmd_eval(&opts),
        "trace" => cmd_trace(&opts),
        "serve" => cmd_serve(&opts),
        "zoo" => cmd_zoo(&opts),
        "devices" => cmd_devices(),
        "storage" => cmd_storage(),
        "solvetime" => cmd_solvetime(),
        "-h" | "--help" | "help" => {
            usage();
            Ok(())
        }
        other => Err(anyhow!("unknown command {other}")),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn usage() {
    println!(
        "carin — Constraint-Aware and Responsive Inference (ACM TECS 2024 reproduction)\n\
         usage: carin <solve|eval|trace|serve|zoo|devices|storage|solvetime> [--uc ucN] [--device p7|s20|a71] [-n N] [--pooled] [--slo MS] [--timeout-mult X]"
    );
}

fn parse_opts(args: &[String]) -> HashMap<String, String> {
    let mut m = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(key) = a.strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                m.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                m.insert(key.to_string(), "true".into());
                i += 1;
            }
        } else if a == "-n" && i + 1 < args.len() {
            m.insert("n".into(), args[i + 1].clone());
            i += 2;
        } else {
            i += 1;
        }
    }
    m
}

fn device_of(opts: &HashMap<String, String>) -> Result<carin::device::Device> {
    let name = opts.get("device").map(|s| s.as_str()).unwrap_or("s20");
    profiles::by_name(name).ok_or_else(|| anyhow!("unknown device {name} (p7|s20|a71)"))
}

fn cmd_solve(opts: &HashMap<String, String>) -> Result<()> {
    let uc = opts.get("uc").map(|s| s.as_str()).unwrap_or("uc1");
    let dev = device_of(opts)?;
    let reg = Registry::paper();
    let p = config::use_case(uc, &reg, &dev).ok_or_else(|| anyhow!("unknown uc {uc}"))?;
    let sol = rass::solve(&p);
    println!("{}", tables::table7_8_designs(&p, &sol));
    Ok(())
}

fn cmd_eval(opts: &HashMap<String, String>) -> Result<()> {
    let reg = Registry::paper();
    let ucs: Vec<&str> = match opts.get("uc").map(|s| s.as_str()) {
        Some("all") | None => vec!["uc1", "uc2", "uc3", "uc4"],
        Some(u) => vec![u],
    };
    for uc in ucs {
        println!("==== {} ====", uc);
        let rows = match uc {
            "uc1" | "uc2" => figures::figure_single(uc, &reg),
            "uc3" => figures::figure_multi(uc, &reg, None),
            "uc4" => figures::figure_multi(uc, &reg, Some(5)),
            other => return Err(anyhow!("unknown uc {other}")),
        };
        println!("{}", figures::render(&rows));
        if opts.contains_key("summary") {
            for method in [
                "B-A",
                "B-S",
                "OODIn",
                "unaware",
                "T_Pixel 7",
                "T_Galaxy S20 FE",
                "T_Galaxy A71",
            ] {
                if let Some((avg, max)) = figures::gain_over(&rows, method) {
                    println!("gain over {method:16}: avg {avg:.2}x  max {max:.2}x");
                }
            }
        }
    }
    Ok(())
}

fn cmd_trace(opts: &HashMap<String, String>) -> Result<()> {
    let uc = opts.get("uc").map(|s| s.as_str()).unwrap_or("uc1");
    let dev = device_of(opts)?;
    let reg = Registry::paper();
    let p = config::use_case(uc, &reg, &dev).ok_or_else(|| anyhow!("unknown uc {uc}"))?;
    let sol = rass::solve(&p);
    println!("{}", tables::table7_8_designs(&p, &sol));
    let sched = if p.is_multi() {
        EventSchedule::figure8(p.device.ram_bytes())
    } else {
        EventSchedule::figure7(p.device.ram_bytes())
    };
    let log = run_trace(&p, sol, sched, 32.0, 1.0 / 24.0, 11);
    println!(
        "trace: {} rounds, {} switches, mean decision {:.0} ns",
        log.points.len(),
        log.switches,
        log.mean_decision_ns
    );
    if let Some(path) = opts.get("json") {
        std::fs::write(path, log.to_json().dump())?;
        println!("trace json -> {path}");
    }
    // condensed timeline: one line per second + every switch/event
    let mut next_mark = 0.0;
    for pt in &log.points {
        let show = pt.switched_to.is_some() || !pt.events.is_empty() || pt.t_s >= next_mark;
        if !show {
            continue;
        }
        next_mark = pt.t_s + 1.0;
        let ev = if pt.events.is_empty() {
            String::new()
        } else {
            format!("  !! {}", pt.events.join("; "))
        };
        let sw = match pt.switched_to {
            Some(d) => format!("  -> switch to d[{d}]"),
            None => String::new(),
        };
        println!(
            "t={:6.2}s design=d[{}] lat={:6.2}ms tp={:6.1}/s acc={:.2} mem={:6.1}MB{}{}",
            pt.t_s,
            pt.design,
            pt.latency_ms[0],
            pt.throughput,
            pt.accuracy[0],
            pt.mem_mb,
            ev,
            sw
        );
    }
    Ok(())
}

fn cmd_serve(opts: &HashMap<String, String>) -> Result<()> {
    let uc = opts.get("uc").map(|s| s.as_str()).unwrap_or("uc1");
    let dev = device_of(opts)?;
    let n: usize = opts.get("n").map(|s| s.parse()).transpose()?.unwrap_or(96);
    let reg = Registry::paper();
    let p = config::use_case(uc, &reg, &dev).ok_or_else(|| anyhow!("unknown uc {uc}"))?;
    let sol = rass::solve(&p);
    println!("design d0: {}", sol.designs[0].describe(&p));
    let manifest = load_manifest(std::path::Path::new("artifacts"))?;

    let mut options = ServeOptions::new()
        .telemetry_path_opt(opts.get("telemetry").map(std::path::PathBuf::from));
    if let Some(slo) = opts.get("slo") {
        options = options.latency_slo_ms(slo.parse::<f64>()?);
    }
    if let Some(mult) = opts.get("timeout-mult") {
        options = options.timeout_mult(mult.parse::<f64>()?);
    }

    let (tx, rx) = std::sync::mpsc::channel();
    let producers = workload::spawn_producers(workload::for_use_case(uc, n), tx, 5, 0.02);

    // Both flavours run PJRT CPU engines behind a watchdog: when an SLO
    // is set, a hung execute is abandoned at the per-call deadline on a
    // sacrificial thread instead of stalling the serve loop.
    let mut single;
    let mut pooled;
    let coord: &mut dyn Coordinator = if opts.contains_key("pooled") {
        // each worker constructs its own supervised PJRT CPU engine as
        // the executable stand-in for its assigned processor
        let factory = |_: carin::device::Engine| {
            carin::runtime::Watchdog::new(carin::runtime::InferenceEngine::cpu)
        };
        pooled = options.build_pooled(factory, &reg, &sol, manifest)?;
        let engines: Vec<&str> = sol.policy.engines.iter().map(|e| e.name()).collect();
        println!(
            "pooled serving: {} engine workers ({})",
            engines.len(),
            engines.join("+")
        );
        &mut pooled
    } else {
        let engine = carin::runtime::Watchdog::new(carin::runtime::InferenceEngine::cpu)?;
        single = options.build_with_engine(engine, &reg, &sol, manifest)?;
        println!("preloaded {} model variants on PJRT CPU", single.loaded_models());
        &mut single
    };
    let report = coord.serve(rx)?;
    if let Some(path) = options.dump_telemetry(coord.telemetry())? {
        let tel = coord.telemetry();
        println!(
            "telemetry: {} events ({} dropped) -> {}, metrics -> {}.prom",
            tel.recorder.len(),
            tel.recorder.dropped(),
            path.display(),
            path.display()
        );
    }
    for h in producers {
        let _ = h.join();
    }
    for t in &report.tasks {
        println!(
            "task {} [{}]: {} done ({} retried, {} failed, {} timed out, {} shed), exec mean {:.2} ms p95 {:.2} ms, e2e mean {:.2} ms",
            t.task,
            t.artifact,
            t.completed,
            t.retried,
            t.failed,
            t.timed_out,
            t.shed,
            t.latency_ms.mean,
            t.latency_ms.percentile(95.0),
            t.e2e_ms.mean
        );
    }
    println!(
        "served {} requests over a {:.2}s window ({:.2}s wall) -> {:.1} req/s ({:.1} goodput), {} fallback / {} recovery switches",
        report.total_requests,
        report.window_s,
        report.wall_s,
        report.throughput_rps,
        report.goodput_rps,
        report.fallback_switches,
        report.recovered_switches
    );
    Ok(())
}

fn cmd_zoo(opts: &HashMap<String, String>) -> Result<()> {
    let reg = Registry::paper();
    let filter = opts.get("uc").map(|s| s.as_str());
    let mut rows = Vec::new();
    for (i, m) in reg.models.iter().enumerate() {
        let uc = match m.task {
            carin::zoo::Task::ImageCls => "uc1",
            carin::zoo::Task::TextCls => "uc2",
            carin::zoo::Task::SceneCls | carin::zoo::Task::AudioCls => "uc3",
            _ => "uc4",
        };
        if let Some(f) = filter {
            if f != "all" && f != uc {
                continue;
            }
        }
        let accs: Vec<String> = Scheme::ALL
            .iter()
            .map(|s| match m.accuracy[s.index()] {
                Some(a) => format!("{a:.2}"),
                None => "-".into(),
            })
            .collect();
        rows.push(vec![
            i.to_string(),
            m.name.to_string(),
            uc.into(),
            format!("{:.2}G", m.gflops),
            format!("{:.2}M", m.mparams),
            accs.join("/"),
            m.artifact.to_string(),
        ]);
    }
    println!(
        "{}",
        harness::render_table(
            &["#", "model", "uc", "FLOPs", "params", "acc fp32/fp16/dr8/fx8/ffx8", "artifact"],
            &rows
        )
    );
    Ok(())
}

fn cmd_devices() -> Result<()> {
    let rows: Vec<Vec<String>> = profiles::all()
        .iter()
        .map(|d| {
            vec![
                d.name.to_string(),
                d.soc.to_string(),
                d.launch.to_string(),
                format!("{:.0} GB", d.ram_gb),
                format!("{} MHz", d.ram_mhz),
                format!("{:.0} W", d.tdp_w),
                d.engines.iter().map(|e| e.name()).collect::<Vec<_>>().join("+"),
            ]
        })
        .collect();
    println!(
        "{}",
        harness::render_table(
            &["device", "SoC", "launch", "RAM", "RAM clk", "TDP", "engines"],
            &rows
        )
    );
    Ok(())
}

fn cmd_storage() -> Result<()> {
    let reg = Registry::paper();
    let rows: Vec<Vec<String>> = tables::table10_storage(&reg)
        .iter()
        .map(|r| {
            vec![
                r.use_case.clone(),
                r.device.clone(),
                format!("{:.2}", r.carin_mb),
                format!("{:.2}", r.oodin_mb),
                format!("{:.2}x", r.reduction),
            ]
        })
        .collect();
    println!(
        "{}",
        harness::render_table(
            &["uc", "device", "CARIn MB", "OODIn MB", "reduction"],
            &rows
        )
    );
    Ok(())
}

fn cmd_solvetime() -> Result<()> {
    let rows: Vec<Vec<String>> = tables::table9_solve_time(&[500, 2000, 5000, 10000], 20, 4)
        .iter()
        .map(|r| {
            vec![
                r.dimension.to_string(),
                format!("{:.3}", r.oodin_avg_ms),
                format!("{:.3}", r.oodin_max_ms),
                format!("{:.0}", r.rass_lookup_avg_ns),
            ]
        })
        .collect();
    println!(
        "{}",
        harness::render_table(
            &["|X|", "OODIn avg ms", "OODIn max ms", "RASS lookup ns"],
            &rows
        )
    );
    Ok(())
}
