//! The application coordinator: the adaptation trace driver used by the
//! runtime-adaptation experiments (Figures 7/8) and the serving front-end
//! (router + dynamic batcher + engine loop) used by the end-to-end
//! example on real PJRT execution.
//!
//! Both serving front-ends are built through [`ServeOptions`] and served
//! through the object-safe [`Coordinator`] trait (see [`api`] for the
//! contract and the migration from the old positional constructors).

pub mod api;
pub mod batcher;
pub mod pool;
pub mod router;
pub mod serve;
pub mod trace;

pub use api::{Coordinator, ServeOptions};
pub use batcher::{Batch, Batcher, Formed};
pub use pool::PooledCoordinator;
pub use router::{RouteTable, Router};
pub use serve::{FaultPolicy, ServeReport, ServeRequest, ServingCoordinator, TaskReport};
pub use trace::{run_trace, TraceLog, TracePoint};
