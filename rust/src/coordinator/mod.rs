//! The application coordinator: the adaptation trace driver used by the
//! runtime-adaptation experiments (Figures 7/8) and the serving front-end
//! (router + dynamic batcher + engine loop) used by the end-to-end
//! example on real PJRT execution.

pub mod batcher;
pub mod pool;
pub mod router;
pub mod serve;
pub mod trace;

pub use batcher::{Batch, Batcher};
pub use pool::PooledCoordinator;
pub use router::Router;
pub use serve::{FaultPolicy, ServeReport, ServeRequest, ServingCoordinator, TaskReport};
pub use trace::{run_trace, TraceLog, TracePoint};
