//! Per-engine worker pool: truly concurrent multi-DNN serving across
//! heterogeneous processors.
//!
//! [`ServingCoordinator`](super::serve::ServingCoordinator) interleaves
//! every task on one engine-owning thread, so a CPU-routed and a
//! GPU-routed model never overlap and one route's retry backoff stalls
//! all serving. [`PooledCoordinator`] replaces that loop with one OS
//! thread per device engine in the solution's switching policy — each
//! worker *constructs and owns its engine locally* (PJRT handles are not
//! `Send`; only an engine factory crosses the spawn boundary) — and a
//! dispatcher thread that admits requests, sheds hopeless deadlines and
//! routes work into per-engine mpsc queues per the active design's
//! task→engine mapping.
//!
//! # Division of labour
//!
//! * **Workers** run supervised execution: batching, retry with capped
//!   backoff, per-request span/latency accounting — all against their
//!   own [`Telemetry`] shard and [`TaskStats`] vector, then report
//!   completions/failures upstream as [`Feedback`]. A backoff sleep on
//!   one engine therefore delays only that engine's queue. Each worker
//!   also pushes the coordinator's per-call watchdog deadline (latency
//!   SLO × `timeout_mult`, floored at `timeout_floor`) into its engine
//!   at spawn, so a *hung* inference is abandoned on that engine alone:
//!   the final attempt surfaces as `timed_out` in the merged report
//!   while every other worker's queue keeps draining.
//! * **The dispatcher** owns the cross-engine state no worker may touch
//!   concurrently: the [`Monitor`], the [`RuntimeManager`], the router
//!   and the fault/probe bookkeeping. Consecutive-failure counting,
//!   fault raising and probe-driven healing consume the feedback stream,
//!   so the supervision semantics match the single-loop coordinator
//!   exactly — they just run off the execution path.
//!
//! # Switch fence
//!
//! A design switch broadcasts `Switch{design, epoch}` to every worker
//! queue. Queues are FIFO, so all work dispatched before the switch
//! drains through the old design first; each worker then flushes its
//! partial batches, loads the new design's artifacts, rebuilds its
//! batchers and acks the epoch. The dispatcher blocks until every
//! worker acks (processing other feedback meanwhile), then repoints its
//! router — no request ever executes against a half-updated routing
//! table.
//!
//! # Report assembly
//!
//! At drain time worker shards merge:
//! [`Telemetry::merge_shards`] re-sorts events on the shared epoch
//! clock and folds counters/gauges/histograms;
//! [`TaskStats::merge_from`] reduces the per-task taxonomy. Per-engine
//! `carin_engine_{queue_depth,queue_depth_peak,busy_ms,jobs_total}`
//! series (labelled `{engine="CPU"}` etc.) make contention between
//! co-located models observable in the Prometheus snapshot.

use std::collections::HashMap;
use std::marker::PhantomData;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::coordinator::batcher::{Batch, Batcher, Formed, Request as BatchRequest};
use crate::coordinator::router::Router;
use crate::coordinator::serve::{
    build_batchers_for, call_deadline, sample_pooled, FaultPolicy, ServeReport, ServeRequest,
    TaskReport, TaskStats,
};
use crate::device::Engine;
use crate::error::CarinError;
use crate::manager::{Monitor, RuntimeManager};
use crate::moo::Solution;
use crate::runtime::engine::{random_input_pooled, Tensor};
use crate::runtime::faults::{fault_kind_of, FaultKind, FaultStats, Inference};
use crate::runtime::{ArtifactId, ArtifactMeta};
use crate::telemetry::{EventKind, Span, Telemetry};
use crate::util::{Backoff, BufferPool, Summary};
use crate::zoo::Registry;

/// Work sent down a per-engine queue. FIFO ordering is what makes the
/// switch fence correct: every `Exec` sent before a `Switch` executes
/// under the old design. Every variant is all-`Copy` payload — nothing
/// allocates to cross the queue (see ROADMAP "Memory path").
enum WorkerMsg {
    Exec {
        task: usize,
        id: u64,
        submitted: Instant,
        admitted: Instant,
        deadline: Option<Instant>,
        /// Manifest index of the artifact serving `task` under the
        /// design active at dispatch time.
        meta_idx: usize,
        seed: u64,
    },
    /// Off-path health probe of a faulted route.
    Probe { route: ArtifactId, seed: u64 },
    /// Fence: flush, rebuild for `design`, then ack `epoch`.
    Switch { design: usize, epoch: u64 },
}

/// Worker → dispatcher feedback. Everything the cross-engine
/// supervision state needs, nothing more.
enum Feedback {
    /// Engine constructed and preload finished (or failed).
    Ready { result: std::result::Result<(), CarinError> },
    /// A request completed; `exec_ms` feeds the shed estimator.
    Done { task: usize, exec_ms: f64 },
    /// A request exhausted its retries.
    Failed { task: usize },
    ProbeResult { engine: Engine, ok: bool },
    SwitchAck { epoch: u64 },
}

/// Everything a worker needs to know about its engine's routes, for
/// every design, computed before the pool spawns.
struct WorkerPlan {
    engine: Engine,
    /// Union of this engine's manifest indices across designs (sorted,
    /// deduped) — the worker-local preload set.
    preload: Vec<usize>,
    /// `per_design[d]` = the `(task, manifest index)` routes this
    /// engine serves under design `d`.
    per_design: Vec<Vec<(usize, usize)>>,
}

/// What a worker thread hands back at join time. Deliberately engine-
/// free so it is `Send` even though the engine itself is not.
struct WorkerOutcome {
    stats: Vec<TaskStats>,
    tel: Telemetry,
    /// Injector counters when the executor is a
    /// [`crate::runtime::FaultInjector`] (the engine itself cannot
    /// leave its thread, so its stats are extracted before drop).
    fault_stats: Option<FaultStats>,
}

/// Health-probe bookkeeping for one faulted route (dispatcher side).
struct ProbeState {
    route: ArtifactId,
    ok: usize,
}

/// The pooled serving coordinator. `F` is the engine factory, called
/// once *inside* each worker thread — the only engine-related value
/// that crosses the spawn boundary. `E` is the executor type every
/// worker builds and owns; it never leaves its thread, so the
/// coordinator only carries it as `PhantomData` (which is what lets
/// [`PooledCoordinator::serve`] be a plain method and the type
/// implement the object-safe [`super::Coordinator`] trait).
pub struct PooledCoordinator<E, F> {
    factory: F,
    router: Router,
    manifest: Vec<ArtifactMeta>,
    n_tasks: usize,
    slo_ms: Option<f64>,
    policy: FaultPolicy,
    monitor: Monitor,
    rm: RuntimeManager,
    tel: Telemetry,
    /// Shared timestamp origin for the dispatcher and every worker
    /// shard, so merged event times are directly comparable.
    epoch: Instant,
    /// Aggregated injector counters from the last run's workers.
    engine_fault_stats: Option<FaultStats>,
    _engine: PhantomData<fn() -> E>,
}

impl<E, F> PooledCoordinator<E, F>
where
    E: Inference,
    F: Fn(Engine) -> Result<E> + Sync,
{
    /// Build the pool coordinator. Unlike
    /// [`super::serve::ServingCoordinator::with_engine`] nothing is
    /// loaded here: each worker constructs its engine and preloads its
    /// own route set when [`PooledCoordinator::serve`] spawns it.
    ///
    /// Crate-internal: external callers build through
    /// [`super::ServeOptions::build_pooled`].
    pub(crate) fn new(
        factory: F,
        reg: &Registry,
        solution: &Solution,
        manifest: Vec<ArtifactMeta>,
    ) -> Result<PooledCoordinator<E, F>> {
        let policy = FaultPolicy::default();
        let router = Router::new(reg, solution, &manifest)?;
        let n_tasks = solution.designs[0].config.assignments.len();
        let monitor = Monitor::new(solution.policy.engines.clone(), policy.hysteresis_hold);
        let rm = RuntimeManager::new(solution.clone());
        let epoch = Instant::now();
        let mut coord = PooledCoordinator {
            factory,
            router,
            manifest,
            n_tasks,
            slo_ms: None,
            policy,
            monitor,
            rm,
            tel: Telemetry::with_epoch(crate::telemetry::DEFAULT_EVENT_CAPACITY, epoch),
            epoch,
            engine_fault_stats: None,
            _engine: PhantomData,
        };
        let d0 = coord.rm.current_design();
        coord.router.set_design(d0);
        coord.tel.registry.set_gauge("carin_current_design", d0 as f64);
        Ok(coord)
    }

    /// Track executions against a latency SLO (ms); misses are reported
    /// per task.
    pub fn set_latency_slo(&mut self, slo_ms: f64) {
        self.slo_ms = Some(slo_ms);
    }

    /// Replace the supervision knobs. Resets the monitor — call between
    /// runs, not mid-serve.
    pub fn set_fault_policy(&mut self, policy: FaultPolicy) {
        self.monitor = Monitor::new(
            self.rm.solution.policy.engines.clone(),
            policy.hysteresis_hold,
        );
        self.policy = policy;
    }

    pub fn n_tasks(&self) -> usize {
        self.n_tasks
    }

    /// The active supervision knobs.
    pub fn fault_policy(&self) -> &FaultPolicy {
        &self.policy
    }

    pub fn current_design(&self) -> usize {
        self.router.design()
    }

    pub fn runtime_manager(&self) -> &RuntimeManager {
        &self.rm
    }

    /// The merged telemetry bundle of the last [`PooledCoordinator::serve`] run
    /// (dispatcher shard + every worker shard).
    pub fn telemetry(&self) -> &Telemetry {
        &self.tel
    }

    pub fn telemetry_mut(&mut self) -> &mut Telemetry {
        &mut self.tel
    }

    /// Aggregated [`crate::runtime::FaultInjector`] counters across the
    /// last run's workers, when the factory builds injecting executors.
    pub fn fault_stats(&self) -> Option<&FaultStats> {
        self.engine_fault_stats.as_ref()
    }

    /// One [`WorkerPlan`] per engine in the switching policy.
    fn worker_plans(&self) -> Vec<WorkerPlan> {
        let n_designs = self.router.n_designs();
        self.rm
            .solution
            .policy
            .engines
            .iter()
            .map(|&engine| {
                let mut preload = Vec::new();
                let mut per_design = Vec::with_capacity(n_designs);
                for d in 0..n_designs {
                    let mut routes = Vec::new();
                    for t in 0..self.n_tasks {
                        let e = self.rm.solution.designs[d].config.assignments[t]
                            .proc
                            .engine();
                        if e == engine {
                            let idx = self.router.route_index_for(d, t);
                            routes.push((t, idx));
                            preload.push(idx);
                        }
                    }
                    per_design.push(routes);
                }
                preload.sort_unstable();
                preload.dedup();
                WorkerPlan { engine, preload, per_design }
            })
            .collect()
    }

    /// Serve a finite workload through the pool: spawn one worker per
    /// policy engine, dispatch until every producer hangs up, then
    /// drain, join and merge the shards. Engine faults never abort the
    /// run — they are retried in-worker, shed around, or routed away
    /// from exactly as in the single-loop coordinator.
    pub fn serve(&mut self, rx: mpsc::Receiver<ServeRequest>) -> Result<ServeReport> {
        let t0 = Instant::now();
        let plans = self.worker_plans();
        let slo_ms = self.slo_ms;
        let n_tasks = self.n_tasks;
        let epoch = self.epoch;
        let policy = self.policy.clone();
        let deadline = call_deadline(&policy, slo_ms);
        self.tel.reset_window();
        let switches_before = self.rm.switches.len();

        let PooledCoordinator {
            ref factory,
            ref manifest,
            ref mut router,
            ref mut monitor,
            ref mut rm,
            ref mut tel,
            ref mut engine_fault_stats,
            ..
        } = *self;
        let manifest: &[ArtifactMeta] = manifest;
        let policy_ref = &policy;

        let engines: Vec<Engine> = plans.iter().map(|p| p.engine).collect();
        let n_workers = engines.len();
        let engine_worker: HashMap<Engine, usize> =
            engines.iter().enumerate().map(|(w, &e)| (e, w)).collect();
        // task → engine per design, so routing needs no RM access on
        // the dispatch path
        let assign_engine: Vec<Vec<Engine>> = (0..router.n_designs())
            .map(|d| {
                (0..n_tasks)
                    .map(|t| rm.solution.designs[d].config.assignments[t].proc.engine())
                    .collect()
            })
            .collect();
        let d0 = router.design();

        let depths: Vec<AtomicUsize> = (0..n_workers).map(|_| AtomicUsize::new(0)).collect();
        let (fb_tx, fb_rx) = mpsc::channel::<Feedback>();
        let mut txs = Vec::with_capacity(n_workers);
        let mut work_rxs = Vec::with_capacity(n_workers);
        for _ in 0..n_workers {
            let (tx, wrx) = mpsc::channel::<WorkerMsg>();
            txs.push(tx);
            work_rxs.push(wrx);
        }

        let mut disp = Dispatcher {
            monitor,
            rm,
            router,
            tel,
            policy: policy_ref,
            manifest,
            engine_worker,
            assign_engine,
            txs,
            fb_rx,
            depths: &depths,
            peak: vec![0; n_workers],
            exec_est: vec![(0.0, 0); n_tasks],
            consecutive: vec![0; n_tasks],
            faulted: HashMap::new(),
            since_probe: 0,
            epoch_ctr: 0,
            shed: vec![0; n_tasks],
            seed: 0,
            t0,
        };

        let outcomes = std::thread::scope(|s| -> Result<Vec<WorkerOutcome>> {
            let mut handles = Vec::with_capacity(n_workers);
            for (w, (plan, wrx)) in plans.into_iter().zip(work_rxs).enumerate() {
                let fb = fb_tx.clone();
                let depth = &depths[w];
                handles.push(s.spawn(move || {
                    run_worker(
                        plan, d0, factory, manifest, policy_ref, deadline, depth, epoch,
                        n_tasks, wrx, fb,
                    )
                }));
            }
            // the dispatcher's copy must go, or fb_rx never disconnects
            drop(fb_tx);

            if let Err(e) = disp.wait_ready(n_workers) {
                // unblock the workers before joining, or the scope
                // deadlocks on threads stuck in recv()
                disp.shutdown();
                for h in handles {
                    let _ = h.join();
                }
                return Err(e);
            }

            for req in rx.iter() {
                disp.admit(req);
            }

            disp.shutdown();
            let mut outcomes = Vec::with_capacity(n_workers);
            for h in handles {
                match h.join() {
                    Ok(o) => outcomes.push(o),
                    Err(_) => return Err(anyhow!("worker thread panicked")),
                }
            }
            // absorb feedback raced with the drain (late Done/Failed)
            disp.drain_feedback();
            Ok(outcomes)
        })?;

        // reclaim the coordinator state the dispatcher borrowed
        let Dispatcher { router, rm, tel, peak, shed, .. } = disp;

        let mut stats: Vec<TaskStats> = (0..n_tasks).map(|_| TaskStats::default()).collect();
        let mut agg_faults: Option<FaultStats> = None;
        let mut shards: Vec<Telemetry> = Vec::with_capacity(n_workers + 1);
        // the dispatcher's shard leads so its admit/shed/supervision
        // events and counters join the same merge
        shards.push(std::mem::replace(tel, Telemetry::with_epoch(1, epoch)));
        for o in outcomes {
            for (t, s) in o.stats.iter().enumerate() {
                stats[t].merge_from(s);
            }
            if let Some(fs) = &o.fault_stats {
                agg_faults.get_or_insert_with(FaultStats::default).absorb(fs);
            }
            shards.push(o.tel);
        }
        for (t, s) in shed.iter().enumerate() {
            stats[t].shed += *s;
        }
        let mut merged = Telemetry::merge_shards(epoch, shards);
        for (w, e) in engines.iter().enumerate() {
            let name = e.name();
            merged.registry.set_gauge(
                &format!("carin_engine_queue_depth{{engine=\"{name}\"}}"),
                depths[w].load(Ordering::Relaxed) as f64,
            );
            merged.registry.set_gauge(
                &format!("carin_engine_queue_depth_peak{{engine=\"{name}\"}}"),
                peak[w] as f64,
            );
        }

        let wall_s = t0.elapsed().as_secs_f64();
        let window_s = merged.window_s().unwrap_or(wall_s).max(1e-9);
        if let Some((a, b)) = merged.window_ns() {
            merged.registry.set_gauge("carin_window_start_s", a as f64 / 1e9);
            merged.registry.set_gauge("carin_window_end_s", b as f64 / 1e9);
        }
        merged.registry.set_gauge("carin_window_s", window_s);
        *tel = merged;
        *engine_fault_stats = agg_faults;

        let total: usize = stats.iter().map(|s| s.completed).sum();
        let met: usize = stats.iter().map(|s| s.deadline_met).sum();
        let switches = &rm.switches[switches_before..];
        let fallback_switches = switches.iter().filter(|s| !s.state.is_calm()).count();
        let recovered_switches = switches.iter().filter(|s| s.state.is_calm()).count();
        let tasks = (0..n_tasks)
            .map(|t| {
                let st = &stats[t];
                TaskReport {
                    task: t,
                    artifact: manifest[router.route_index(t)].stem.clone(),
                    completed: st.completed,
                    retried: st.retried,
                    retried_timeout: st.retried_timeout,
                    failed: st.failed,
                    timed_out: st.timed_out,
                    shed: st.shed,
                    deadline_met: st.deadline_met,
                    slo_misses: match slo_ms {
                        Some(slo) => st.lat.iter().filter(|&&x| x > slo).count(),
                        None => 0,
                    },
                    latency_ms: Summary::of_or_empty(&st.lat),
                    e2e_ms: Summary::of_or_empty(&st.e2e),
                }
            })
            .collect();
        Ok(ServeReport {
            tasks,
            wall_s,
            window_s,
            total_requests: total,
            throughput_rps: total as f64 / window_s,
            goodput_rps: met as f64 / window_s,
            retried: stats.iter().map(|s| s.retried).sum(),
            retried_timeout: stats.iter().map(|s| s.retried_timeout).sum(),
            failed: stats.iter().map(|s| s.failed).sum(),
            timed_out: stats.iter().map(|s| s.timed_out).sum(),
            shed: stats.iter().map(|s| s.shed).sum(),
            fallback_switches,
            recovered_switches,
        })
    }
}

/// The dispatcher's working state: everything cross-engine, borrowed
/// from the coordinator for the duration of one `serve` run.
struct Dispatcher<'a> {
    monitor: &'a mut Monitor,
    rm: &'a mut RuntimeManager,
    router: &'a mut Router,
    tel: &'a mut Telemetry,
    policy: &'a FaultPolicy,
    manifest: &'a [ArtifactMeta],
    engine_worker: HashMap<Engine, usize>,
    /// `assign_engine[design][task]` — the engine serving a task.
    assign_engine: Vec<Vec<Engine>>,
    txs: Vec<mpsc::Sender<WorkerMsg>>,
    fb_rx: mpsc::Receiver<Feedback>,
    depths: &'a [AtomicUsize],
    peak: Vec<usize>,
    /// Running (sum, count) of per-task exec latency for shedding.
    exec_est: Vec<(f64, u64)>,
    /// Consecutive exhausted-retry failures per task.
    consecutive: Vec<usize>,
    faulted: HashMap<Engine, ProbeState>,
    since_probe: usize,
    epoch_ctr: u64,
    shed: Vec<usize>,
    seed: u64,
    t0: Instant,
}

impl Dispatcher<'_> {
    /// Block until every worker reports its engine built and preloaded.
    fn wait_ready(&mut self, n_workers: usize) -> Result<()> {
        let mut first_err: Option<CarinError> = None;
        let mut ready = 0usize;
        while ready < n_workers {
            match self.fb_rx.recv() {
                Ok(Feedback::Ready { result }) => {
                    ready += 1;
                    if let Err(e) = result {
                        if first_err.is_none() {
                            first_err = Some(e);
                        }
                    }
                }
                Ok(other) => self.handle_feedback(other),
                Err(_) => return Err(anyhow!("worker pool hung up during startup")),
            }
        }
        match first_err {
            Some(e) => Err(anyhow!("worker preload failed: {e}")),
            None => Ok(()),
        }
    }

    /// Admit one request: record it, run the supervision tick, shed if
    /// its deadline is unreachable, else route it to its engine's queue.
    fn admit(&mut self, req: ServeRequest) {
        self.seed += 1;
        let admitted_at = Instant::now();
        self.tel.note_admit();
        self.tel
            .recorder
            .record(EventKind::Admitted { task: req.task as u32, id: req.id });
        self.tel.registry.inc("carin_requests_admitted_total");

        self.drain_feedback();
        self.observe_and_maybe_switch();
        self.maybe_probe();

        let t = req.task;
        if let Some(dl) = req.deadline {
            let (sum, cnt) = self.exec_est[t];
            let est_ms = if cnt == 0 { 0.0 } else { sum / cnt as f64 };
            let est = Duration::from_secs_f64(est_ms / 1000.0);
            if dl.saturating_duration_since(Instant::now()) < est {
                self.shed[t] += 1;
                self.tel.recorder.record(EventKind::Shed { task: t as u32, id: req.id });
                self.tel.registry.inc("carin_requests_shed_total");
                return;
            }
        }

        let meta_idx = self.router.route_index(t);
        let e = self.assign_engine[self.router.design()][t];
        let w = self.engine_worker.get(&e).copied().unwrap_or(0);
        let depth = self.depths[w].fetch_add(1, Ordering::Relaxed) + 1;
        if depth > self.peak[w] {
            self.peak[w] = depth;
        }
        let _ = self.txs[w].send(WorkerMsg::Exec {
            task: t,
            id: req.id,
            submitted: req.submitted,
            admitted: admitted_at,
            deadline: req.deadline,
            meta_idx,
            seed: self.seed,
        });
    }

    /// Absorb every queued feedback message without blocking.
    fn drain_feedback(&mut self) {
        loop {
            let fb = match self.fb_rx.try_recv() {
                Ok(fb) => fb,
                Err(_) => break,
            };
            self.handle_feedback(fb);
        }
    }

    fn handle_feedback(&mut self, fb: Feedback) {
        match fb {
            Feedback::Done { task, exec_ms } => {
                self.consecutive[task] = 0;
                let (sum, cnt) = &mut self.exec_est[task];
                *sum += exec_ms;
                *cnt += 1;
            }
            Feedback::Failed { task } => {
                self.consecutive[task] += 1;
                if self.consecutive[task] >= self.policy.fault_threshold {
                    let e = self.assign_engine[self.router.design()][task];
                    let route = self.router.route(task);
                    self.monitor.report_fault(e, true);
                    if !self.faulted.contains_key(&e) {
                        crate::log_warn!(
                            "fault raised on {} after {} consecutive failures (task {task}, route {})",
                            e.name(),
                            self.consecutive[task],
                            self.router.table().name(route)
                        );
                        self.faulted.insert(e, ProbeState { route, ok: 0 });
                        self.tel.recorder.record(EventKind::FaultRaised {
                            engine: e.index() as u8,
                            task: task as u32,
                        });
                        self.tel.registry.inc("carin_faults_raised_total");
                    }
                    self.tel
                        .registry
                        .set_gauge("carin_fault_raw_mask", self.monitor.raw_fault_mask() as f64);
                }
            }
            Feedback::ProbeResult { engine, ok } => {
                self.tel
                    .recorder
                    .record(EventKind::Probe { engine: engine.index() as u8, ok });
                self.tel.registry.inc("carin_probes_total");
                let mut healed = false;
                if let Some(p) = self.faulted.get_mut(&engine) {
                    if ok {
                        p.ok += 1;
                        healed = p.ok >= self.policy.heal_threshold;
                    } else {
                        p.ok = 0;
                    }
                }
                if healed {
                    crate::log_info!(
                        "fault cleared on {} after consecutive probe successes",
                        engine.name()
                    );
                    self.monitor.report_fault(engine, false);
                    self.faulted.remove(&engine);
                    self.tel
                        .recorder
                        .record(EventKind::FaultCleared { engine: engine.index() as u8 });
                    self.tel.registry.inc("carin_faults_cleared_total");
                    self.tel
                        .registry
                        .set_gauge("carin_fault_raw_mask", self.monitor.raw_fault_mask() as f64);
                }
            }
            // Ready outside startup and stale acks carry no state
            Feedback::Ready { .. } | Feedback::SwitchAck { .. } => {}
        }
    }

    /// Advance the monitor; on an RM decision run the epoch fence.
    fn observe_and_maybe_switch(&mut self) {
        let state = self.monitor.tick();
        if let Some(d) = self.rm.observe(state, self.t0.elapsed().as_secs_f64()) {
            if let Some(rec) = self.rm.switches.last() {
                let fallback = !rec.state.is_calm();
                crate::log_info!(
                    "{} switch d[{}] -> d[{}] (bad_mask {:#04b}, {} ns decision)",
                    if fallback { "fallback" } else { "recovery" },
                    rec.from,
                    rec.to,
                    rec.bad_mask,
                    rec.decision_ns
                );
                self.tel.recorder.record(EventKind::Switch {
                    from: rec.from as u32,
                    to: rec.to as u32,
                    troubled: rec.state.troubled,
                    faulted: rec.state.faulted,
                    memory: rec.state.memory,
                    bad_mask: rec.bad_mask,
                    decision_ns: rec.decision_ns as u64,
                    fallback,
                });
                let name = if fallback {
                    "carin_switches_fallback_total"
                } else {
                    "carin_switches_recovery_total"
                };
                let decision_ns = rec.decision_ns as f64;
                self.tel.registry.inc(name);
                self.tel.registry.observe("carin_switch_decision_ns", decision_ns);
            }
            self.fence_switch(d);
        }
    }

    /// The coordinated switch epoch: broadcast, collect every worker's
    /// ack (handling interleaved feedback), then repoint the router.
    fn fence_switch(&mut self, design: usize) {
        self.epoch_ctr += 1;
        let ep = self.epoch_ctr;
        for tx in &self.txs {
            let _ = tx.send(WorkerMsg::Switch { design, epoch: ep });
        }
        let mut acked = 0usize;
        while acked < self.txs.len() {
            let fb = match self.fb_rx.recv() {
                Ok(fb) => fb,
                // a vanished worker cannot ack; give up on the fence
                // rather than hang (its queue is gone anyway)
                Err(_) => break,
            };
            match fb {
                Feedback::SwitchAck { epoch } if epoch == ep => acked += 1,
                other => self.handle_feedback(other),
            }
        }
        self.router.set_design(design);
        self.tel.registry.set_gauge("carin_current_design", design as f64);
    }

    /// Every `probe_interval` admissions, ask each faulted engine's
    /// worker to health-probe its failing route. The result arrives as
    /// feedback; healing happens when it is processed.
    fn maybe_probe(&mut self) {
        self.since_probe += 1;
        if self.faulted.is_empty() || self.since_probe < self.policy.probe_interval {
            return;
        }
        self.since_probe = 0;
        for (e, p) in &self.faulted {
            if let Some(&w) = self.engine_worker.get(e) {
                let _ = self.txs[w].send(WorkerMsg::Probe { route: p.route, seed: self.seed });
            }
        }
    }

    /// Drop every work queue: workers drain what is already queued,
    /// flush pending batches and exit.
    fn shutdown(&mut self) {
        self.txs.clear();
    }
}

/// Worker thread body: build the engine locally, preload this engine's
/// route set, then serve the queue until the dispatcher hangs up.
#[allow(clippy::too_many_arguments)]
fn run_worker<E, F>(
    plan: WorkerPlan,
    start_design: usize,
    factory: &F,
    manifest: &[ArtifactMeta],
    policy: &FaultPolicy,
    deadline: Option<Duration>,
    depth: &AtomicUsize,
    epoch: Instant,
    n_tasks: usize,
    rx: mpsc::Receiver<WorkerMsg>,
    fb: mpsc::Sender<Feedback>,
) -> WorkerOutcome
where
    E: Inference,
    F: Fn(Engine) -> Result<E>,
{
    let engine_id = plan.engine;
    let tel = Telemetry::with_epoch(crate::telemetry::DEFAULT_EVENT_CAPACITY, epoch);
    let stats: Vec<TaskStats> = (0..n_tasks).map(|_| TaskStats::default()).collect();
    let mut engine = match factory(engine_id) {
        Ok(e) => e,
        Err(e) => {
            let _ = fb.send(Feedback::Ready { result: Err(CarinError::Engine(e.to_string())) });
            return WorkerOutcome { stats, tel, fault_stats: None };
        }
    };
    engine.set_call_deadline(deadline);
    let mut preload_err: Option<CarinError> = None;
    for &idx in &plan.preload {
        // interned ids are manifest indices by construction (RouteTable)
        let route = ArtifactId(idx as u32);
        if let Err(e) = supervised_load(&mut engine, route, &manifest[idx], policy) {
            preload_err = Some(CarinError::Artifact(format!("{}: {e}", manifest[idx].stem)));
            break;
        }
    }
    let _ = fb.send(Feedback::Ready {
        result: match &preload_err {
            Some(e) => Err(e.clone()),
            None => Ok(()),
        },
    });
    if preload_err.is_some() {
        let fault_stats = engine.fault_stats();
        return WorkerOutcome { stats, tel, fault_stats };
    }

    let routes = plan.per_design[start_design].clone();
    let pool = BufferPool::default();
    let batchers = build_batchers_for(manifest, &routes, &pool);
    let mut worker = Worker {
        engine,
        engine_id,
        plan,
        design: start_design,
        manifest,
        policy,
        deadline,
        batchers,
        stats,
        tel,
        fb,
        pool,
        busy: Duration::ZERO,
        jobs: 0,
    };
    worker.run(rx, depth);
    worker.finish()
}

/// Retrying model load (shared by preload and switch reloads).
fn supervised_load<E: Inference>(
    engine: &mut E,
    route: ArtifactId,
    meta: &ArtifactMeta,
    policy: &FaultPolicy,
) -> Result<()> {
    let mut backoff = Backoff::new(policy.backoff_base, policy.backoff_cap);
    let mut attempt = 0usize;
    loop {
        attempt += 1;
        match engine.load(route, meta) {
            Ok(()) => return Ok(()),
            Err(e) => {
                if attempt >= policy.max_attempts {
                    return Err(e);
                }
                std::thread::sleep(backoff.next_delay());
            }
        }
    }
}

/// One engine-owning worker: the single-loop execution semantics
/// (batching, supervision, span accounting), scoped to one engine's
/// queue and recording into its own telemetry shard.
struct Worker<'a, E: Inference> {
    engine: E,
    engine_id: Engine,
    plan: WorkerPlan,
    design: usize,
    manifest: &'a [ArtifactMeta],
    policy: &'a FaultPolicy,
    /// Per-call watchdog deadline pushed into the engine at spawn;
    /// kept for the `timed_out` event payload.
    deadline: Option<Duration>,
    batchers: HashMap<usize, Batcher>,
    stats: Vec<TaskStats>,
    tel: Telemetry,
    fb: mpsc::Sender<Feedback>,
    /// Worker-local lease pool for input payloads and batch formation;
    /// its traffic is published into the shard registry at finish.
    pool: BufferPool,
    /// Wall time spent executing (engine calls incl. retries/backoff).
    busy: Duration,
    jobs: u64,
}

impl<E: Inference> Worker<'_, E> {
    fn run(&mut self, rx: mpsc::Receiver<WorkerMsg>, depth: &AtomicUsize) {
        loop {
            // with a partial batch pending, poll so its 5 ms batching
            // deadline can fire even if the queue goes quiet
            let has_pending = self.batchers.values().any(|b| b.pending() > 0);
            let msg = if has_pending {
                match rx.recv_timeout(Duration::from_millis(1)) {
                    Ok(m) => m,
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        self.flush_due();
                        continue;
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                }
            } else {
                match rx.recv() {
                    Ok(m) => m,
                    Err(_) => break,
                }
            };
            match msg {
                WorkerMsg::Exec { task, id, submitted, admitted, deadline, meta_idx, seed } => {
                    depth.fetch_sub(1, Ordering::Relaxed);
                    self.flush_due();
                    let t_busy = Instant::now();
                    self.handle_exec(task, id, submitted, admitted, deadline, meta_idx, seed);
                    self.busy += t_busy.elapsed();
                    self.jobs += 1;
                }
                WorkerMsg::Probe { route, seed } => {
                    let ok = match self.manifest.get(route.index()) {
                        Some(meta) => {
                            let input = random_input_pooled(meta, seed, &self.pool);
                            self.engine.infer(route, &input).is_ok()
                        }
                        None => false,
                    };
                    let _ = self.fb.send(Feedback::ProbeResult { engine: self.engine_id, ok });
                }
                WorkerMsg::Switch { design, epoch } => {
                    self.apply_switch(design);
                    let _ = self.fb.send(Feedback::SwitchAck { epoch });
                }
            }
        }
        // queue closed: drain partial batches through current routes
        self.flush_pending();
    }

    /// Seal the shard: per-engine busy/jobs series and the worker pool's
    /// lease traffic, then hand back the `Send` parts (the engine drops
    /// here, on its owning thread).
    fn finish(self) -> WorkerOutcome {
        let Worker { engine, engine_id, mut tel, stats, busy, jobs, pool, .. } = self;
        let name = engine_id.name();
        tel.registry.set_gauge(
            &format!("carin_engine_busy_ms{{engine=\"{name}\"}}"),
            busy.as_secs_f64() * 1000.0,
        );
        tel.registry
            .add(&format!("carin_engine_jobs_total{{engine=\"{name}\"}}"), jobs);
        pool.sweep_returns();
        let ps = pool.stats();
        tel.registry.add("carin_bufpool_hits", ps.hits);
        tel.registry.add("carin_bufpool_misses", ps.misses);
        tel.registry.add("carin_bufpool_returns", ps.returns);
        let fault_stats = engine.fault_stats();
        WorkerOutcome { stats, tel, fault_stats }
    }

    #[allow(clippy::too_many_arguments)]
    fn handle_exec(
        &mut self,
        t: usize,
        id: u64,
        submitted: Instant,
        admitted: Instant,
        deadline: Option<Instant>,
        meta_idx: usize,
        seed: u64,
    ) {
        let route = ArtifactId(meta_idx as u32);
        if self.batchers.contains_key(&t) {
            let sample_len = {
                let meta = &self.manifest[meta_idx];
                meta.input.numel() / meta.input.shape[0]
            };
            self.tel.recorder.record(EventKind::Batched { task: t as u32, id });
            let pushed = self.batchers.get_mut(&t).unwrap().push(BatchRequest {
                id,
                payload: sample_pooled(sample_len, seed, &self.pool),
                enqueued: submitted,
                admitted,
                deadline,
            });
            match pushed {
                Ok(formed) => self.finish_formed(t, route, formed),
                Err(e) => {
                    // a rejected payload (shape mismatch) fails the
                    // request without feeding the engine-fault counter
                    self.stats[t].failed += 1;
                    self.tel.recorder.record(EventKind::Failed { task: t as u32, id });
                    self.tel.registry.inc("carin_requests_failed_total");
                    crate::log_warn!("task {t} request {id} rejected: {e}");
                }
            }
        } else {
            let input = random_input_pooled(&self.manifest[meta_idx], seed, &self.pool);
            self.execute_one(t, route, &input, id, submitted, admitted, deadline);
        }
    }

    /// Shed + execute the outcome of one batch-formation attempt.
    fn finish_formed(&mut self, t: usize, route: ArtifactId, formed: Formed) {
        for r in &formed.shed {
            self.stats[t].shed += 1;
            self.tel.recorder.record(EventKind::Shed { task: t as u32, id: r.id });
            self.tel.registry.inc("carin_requests_shed_total");
        }
        if let Some(batch) = formed.batch {
            self.execute_batch(t, route, batch);
        }
    }

    /// One supervised engine call with capped exponential backoff — the
    /// sleep only ever delays this worker's queue.
    fn supervised_infer(&mut self, t: usize, route: ArtifactId, input: &Tensor) -> Result<f64> {
        let mut backoff = Backoff::new(self.policy.backoff_base, self.policy.backoff_cap);
        let mut attempt = 0usize;
        let mut timed_out_attempts = 0usize;
        loop {
            attempt += 1;
            let te = Instant::now();
            match self.engine.infer(route, input) {
                Ok(_) => {
                    if attempt > 1 {
                        self.stats[t].retried += 1;
                        if timed_out_attempts > 0 {
                            self.stats[t].retried_timeout += 1;
                            self.tel.registry.inc("carin_requests_retried_timeout_total");
                        }
                        self.tel.recorder.record(EventKind::Retried {
                            task: t as u32,
                            attempts: attempt as u32,
                        });
                        self.tel.registry.inc("carin_requests_retried_total");
                    }
                    return Ok(te.elapsed().as_secs_f64() * 1000.0);
                }
                Err(e) => {
                    if fault_kind_of(&e) == Some(FaultKind::Timeout) {
                        timed_out_attempts += 1;
                        self.tel.registry.inc("carin_engine_timeouts_total");
                    }
                    if attempt >= self.policy.max_attempts {
                        return Err(e);
                    }
                    std::thread::sleep(backoff.next_delay());
                }
            }
        }
    }

    /// Shard bookkeeping for one completed request (see
    /// [`super::serve::ServingCoordinator`] for the span semantics).
    fn note_completion(&mut self, span: &Span, exec_ms: f64, met: bool) {
        span.record(&mut self.tel.recorder, met);
        self.tel.note_done();
        let r = &mut self.tel.registry;
        r.inc("carin_requests_completed_total");
        if met {
            r.inc("carin_requests_deadline_met_total");
        }
        r.observe("carin_exec_latency_ms", exec_ms);
        r.observe("carin_e2e_latency_ms", span.total_ms());
        r.observe("carin_queue_latency_ms", span.queue_ms());
        r.observe("carin_batch_wait_ms", span.batch_ms());
    }

    #[allow(clippy::too_many_arguments)]
    fn execute_one(
        &mut self,
        t: usize,
        route: ArtifactId,
        input: &Tensor,
        id: u64,
        submitted: Instant,
        admitted: Instant,
        deadline: Option<Instant>,
    ) {
        let dispatched = Instant::now();
        self.tel.recorder.record(EventKind::Dispatched {
            task: t as u32,
            route: route.0,
            occupancy: 1,
        });
        self.tel.registry.inc("carin_engine_dispatch_total");
        match self.supervised_infer(t, route, input) {
            Ok(exec_ms) => {
                let done = Instant::now();
                let met = match deadline {
                    Some(dl) => done <= dl,
                    None => true,
                };
                {
                    let st = &mut self.stats[t];
                    st.lat.push(exec_ms);
                    st.exec_sum_ms += exec_ms;
                    st.e2e.push(done.duration_since(submitted).as_secs_f64() * 1000.0);
                    st.completed += 1;
                    if met {
                        st.deadline_met += 1;
                    }
                }
                let span = Span { task: t, id, submitted, admitted, dispatched, completed: done };
                self.note_completion(&span, exec_ms, met);
                let _ = self.fb.send(Feedback::Done { task: t, exec_ms });
            }
            Err(e) => {
                if fault_kind_of(&e) == Some(FaultKind::Timeout) {
                    self.stats[t].timed_out += 1;
                    let span = Span {
                        task: t,
                        id,
                        submitted,
                        admitted,
                        dispatched,
                        completed: Instant::now(),
                    };
                    span.record_timeout(
                        &mut self.tel.recorder,
                        self.deadline.unwrap_or_default(),
                    );
                    self.tel.registry.inc("carin_requests_timed_out_total");
                } else {
                    self.stats[t].failed += 1;
                    self.tel.recorder.record(EventKind::Failed { task: t as u32, id });
                    self.tel.registry.inc("carin_requests_failed_total");
                }
                let _ = self.fb.send(Feedback::Failed { task: t });
            }
        }
    }

    fn execute_batch(&mut self, t: usize, route: ArtifactId, batch: Batch) {
        let Batch { ids, payload, occupancy, enqueued, admitted, deadlines } = batch;
        let input = Tensor::F32(payload);
        let dispatched = Instant::now();
        self.tel.recorder.record(EventKind::Dispatched {
            task: t as u32,
            route: route.0,
            occupancy: occupancy as u32,
        });
        self.tel.registry.inc("carin_engine_dispatch_total");
        match self.supervised_infer(t, route, &input) {
            Ok(exec_ms) => {
                let done = Instant::now();
                for i in 0..occupancy {
                    let met = match deadlines[i] {
                        Some(dl) => done <= dl,
                        None => true,
                    };
                    {
                        let st = &mut self.stats[t];
                        st.lat.push(exec_ms);
                        st.exec_sum_ms += exec_ms;
                        st.e2e.push(done.duration_since(enqueued[i]).as_secs_f64() * 1000.0);
                        st.completed += 1;
                        if met {
                            st.deadline_met += 1;
                        }
                    }
                    let span = Span {
                        task: t,
                        id: ids[i],
                        submitted: enqueued[i],
                        admitted: admitted[i],
                        dispatched,
                        completed: done,
                    };
                    self.note_completion(&span, exec_ms, met);
                }
                let _ = self.fb.send(Feedback::Done { task: t, exec_ms });
            }
            Err(e) => {
                if fault_kind_of(&e) == Some(FaultKind::Timeout) {
                    self.stats[t].timed_out += occupancy;
                    let completed = Instant::now();
                    let d = self.deadline.unwrap_or_default();
                    for i in 0..occupancy {
                        let span = Span {
                            task: t,
                            id: ids[i],
                            submitted: enqueued[i],
                            admitted: admitted[i],
                            dispatched,
                            completed,
                        };
                        span.record_timeout(&mut self.tel.recorder, d);
                        self.tel.registry.inc("carin_requests_timed_out_total");
                    }
                } else {
                    self.stats[t].failed += occupancy;
                    for &id in ids.iter().take(occupancy) {
                        self.tel.recorder.record(EventKind::Failed { task: t as u32, id });
                        self.tel.registry.inc("carin_requests_failed_total");
                    }
                }
                // one fault-accounting signal per exhausted engine call,
                // matching the single loop's note_failure semantics
                let _ = self.fb.send(Feedback::Failed { task: t });
            }
        }
    }

    /// Fence arrival: flush through the old routes, adopt the design,
    /// make its artifacts resident and rebuild the batchers.
    fn apply_switch(&mut self, design: usize) {
        self.flush_pending();
        self.design = design;
        let routes = self.plan.per_design[design].clone();
        for &(_, idx) in &routes {
            let route = ArtifactId(idx as u32);
            if !self.engine.is_loaded(route) {
                // a failed load leaves the route cold: its requests fail
                // supervision and re-raise the fault signal, so the
                // policy moves on rather than this worker dying
                let _ =
                    supervised_load(&mut self.engine, route, &self.manifest[idx], self.policy);
            }
        }
        self.batchers = build_batchers_for(self.manifest, &routes, &self.pool);
    }

    /// Interned route serving `t` under this worker's current design.
    fn route_of(&self, t: usize) -> Option<ArtifactId> {
        self.plan.per_design[self.design]
            .iter()
            .find(|&&(task, _)| task == t)
            .map(|&(_, idx)| ArtifactId(idx as u32))
    }

    fn flush_due(&mut self) {
        let now = Instant::now();
        let tasks: Vec<usize> = self.batchers.keys().copied().collect();
        for t in tasks {
            let maybe = self.batchers.get_mut(&t).map(|b| b.flush_due(now));
            if let Some(formed) = maybe {
                if let Some(route) = self.route_of(t) {
                    self.finish_formed(t, route, formed);
                }
            }
        }
    }

    fn flush_pending(&mut self) {
        let tasks: Vec<usize> = self.batchers.keys().copied().collect();
        for t in tasks {
            let maybe = self.batchers.get_mut(&t).map(|b| b.flush());
            if let Some(formed) = maybe {
                if let Some(route) = self.route_of(t) {
                    self.finish_formed(t, route, formed);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;
    use crate::runtime::synthetic_manifest;

    #[test]
    fn worker_plans_partition_tasks_by_engine() {
        let reg = Registry::paper();
        let sol = config::pinned_uc3_solution(&reg);
        let manifest = synthetic_manifest(&reg);
        let factory = |_: Engine| -> Result<crate::runtime::StubEngine> {
            Ok(crate::runtime::StubEngine::new())
        };
        let coord = PooledCoordinator::new(factory, &reg, &sol, manifest).unwrap();
        let plans = coord.worker_plans();
        assert_eq!(plans.len(), 2, "one worker per policy engine");
        assert_eq!(plans[0].engine, Engine::Cpu);
        assert_eq!(plans[1].engine, Engine::Gpu);
        // the pinned solution has a single design: task 0 on CPU,
        // task 1 on GPU — each plan carries exactly its own route
        assert_eq!(plans[0].per_design.len(), 1);
        assert_eq!(plans[0].per_design[0].len(), 1);
        assert_eq!(plans[0].per_design[0][0].0, 0);
        assert_eq!(plans[1].per_design[0].len(), 1);
        assert_eq!(plans[1].per_design[0][0].0, 1);
        // preload sets are disjoint and singleton
        assert_eq!(plans[0].preload.len(), 1);
        assert_eq!(plans[1].preload.len(), 1);
        assert_ne!(plans[0].preload[0], plans[1].preload[0]);
    }

    #[test]
    fn preload_failure_surfaces_as_error_not_hang() {
        let reg = Registry::paper();
        let sol = config::pinned_uc3_solution(&reg);
        let manifest = synthetic_manifest(&reg);
        let factory = |_: Engine| -> Result<crate::runtime::FaultInjector<crate::runtime::StubEngine>> {
            let mut inj = crate::runtime::FaultInjector::new(crate::runtime::StubEngine::new(), 7);
            inj.set_default(crate::runtime::FaultSpec::transient(0.0).with_load_failures(1.0));
            Ok(inj)
        };
        let mut coord = PooledCoordinator::new(factory, &reg, &sol, manifest).unwrap();
        let (tx, rx) = mpsc::channel();
        drop(tx);
        let err = coord.serve(rx).expect_err("persistent load failure must propagate");
        assert!(err.to_string().contains("preload failed"), "{err}");
    }
}
