//! Serving front-end over the inference engine: workload threads feed a
//! request channel; requests route through the Runtime-Manager-selected
//! design, batch where the model expects a batch, execute under
//! supervision, and report per-request latency.
//!
//! # Threading model
//!
//! Two coordinators share this machinery:
//!
//! * [`ServingCoordinator`] (this module) — the **single-loop** form:
//!   one thread owns the engine (PJRT types are not `Send`, so the
//!   engine lives on its owning thread) and serially interleaves every
//!   task's requests. Simple, deterministic, and the baseline the
//!   `parallel_serving` bench compares against — but a CPU-routed and a
//!   GPU-routed model never truly overlap, and a retry backoff sleep
//!   stalls the whole loop.
//! * [`PooledCoordinator`](super::pool::PooledCoordinator) — the
//!   **per-engine worker pool**: one OS thread per device engine, each
//!   constructing and owning its engine locally, fed by per-engine mpsc
//!   queues the dispatcher routes into per the active design's
//!   task→engine mapping. Supervision, backoff and health probes run
//!   *inside* each worker, so a backoff on one engine no longer delays
//!   the others and multi-DNN wall-clock scales with the number of
//!   healthy engines.
//!
//! # Switch epoch protocol (pooled path)
//!
//! A design switch must not let requests execute against a half-updated
//! routing table. The pooled dispatcher turns each switch into an
//! epoch: it broadcasts a `Switch{design, epoch}` message down every
//! worker queue (FIFO — all work dispatched before the switch drains
//! through the old design first), then blocks dispatching until every
//! worker acknowledges the epoch. On receipt each worker flushes its
//! pending partial batches through the old routes, loads the new
//! design's artifacts, rebuilds its batchers and acks. Only then does
//! the dispatcher repoint its router and resume — the same
//! flush→repoint→reload→rebatch sequence [`ServingCoordinator`] runs
//! inline, made coordination-safe across threads.
//!
//! # Telemetry sharding (pooled path)
//!
//! Hot-path recording stays O(1) and allocation-free by giving every
//! worker its own [`Telemetry`] shard sharing one epoch instant;
//! [`crate::telemetry::Telemetry::merge_shards`] reduces them at report
//! time (events re-sort by timestamp, counters add, histograms merge
//! bucket-wise). `ServeReport` aggregation is likewise a reduction over
//! worker-local [`TaskStats`] via [`TaskStats::merge_from`].
//!
//! # Fault model & recovery semantics
//!
//! The coordinator treats inference failure, slow execution and overload
//! as first-class runtime states rather than process-terminating errors:
//!
//! * **Supervised execution** — every engine call is retried up to
//!   [`FaultPolicy::max_attempts`] times with capped exponential backoff
//!   ([`crate::util::Backoff`]). A request whose retries are exhausted is
//!   counted `failed`, never propagated as a process error.
//! * **Timeout supervision** — a hung engine call (fail-slow, not
//!   fail-stop) is bounded by a per-call watchdog deadline of
//!   `max(SLO × timeout_mult, timeout_floor)` (see
//!   [`FaultPolicy::timeout_mult`]); the coordinator pushes it into the
//!   executor stack via [`Inference::set_call_deadline`], and a
//!   [`crate::runtime::Watchdog`]-wrapped executor abandons the hung
//!   thread when it fires. A timed-out attempt counts toward the same
//!   consecutive-failure fault raising as an error, retries under the
//!   same backoff, and — when retries are exhausted — is counted
//!   `timed_out` (disjoint from `failed`) with a `timed_out` event and
//!   the `carin_engine_timeouts_total` / `carin_requests_timed_out_total`
//!   counters.
//! * **Fault signaling** — after [`FaultPolicy::fault_threshold`]
//!   consecutive exhausted-retry failures on a task, the engine carrying
//!   that task's route is reported *faulted* to the [`Monitor`]; the
//!   debounced [`EnvState::faulted`] bit drives the existing RASS
//!   switching policy, which falls back to a design avoiding the engine.
//!   Every [`FaultPolicy::probe_interval`] requests the faulted route is
//!   health-probed off the request path; after
//!   [`FaultPolicy::heal_threshold`] consecutive probe successes the
//!   signal clears and the policy recovers to the calm design.
//! * **Deadline-aware admission** — each [`ServeRequest`] may carry a
//!   deadline derived from its task's SLO. A request whose remaining
//!   budget is smaller than the task's running mean execution latency is
//!   *shed at dequeue* (counted `shed`, not executed), protecting the
//!   goodput of requests that can still make their deadlines.
//!
//! # Report taxonomy
//!
//! [`TaskReport`] counts per task: `completed` (successful executions),
//! `retried` (engine calls that needed at least one retry),
//! `retried_timeout` (the subset of retried calls where a prior attempt
//! hit the watchdog deadline), `failed` (requests whose retries were
//! exhausted on an error), `timed_out` (requests whose retries were
//! exhausted with the final attempt abandoned by the watchdog — disjoint
//! from `failed`, so `completed + failed + shed + timed_out` accounts
//! for every admitted request), `shed` (deadline-shed at dequeue) and
//! `deadline_met` (completed in time; equals `completed`
//! for deadline-free requests). [`ServeReport`] aggregates these and adds
//! `goodput_rps` (successful-within-deadline requests per second),
//! `fallback_switches` (design switches taken while a fault/overload
//! signal was raised) and `recovered_switches` (switches back after the
//! signal cleared). Both rates are computed over the *serving window*
//! (first admission → last completion), not the loop's wall clock, so
//! producer warm-up and drain time do not dilute them.
//!
//! # Telemetry
//!
//! The coordinator owns a [`Telemetry`] bundle: every admission, shed,
//! dispatch, retry, completion, fault transition, probe and design
//! switch is recorded as a typed event in a bounded ring buffer, each
//! completed request carries a [`Span`] with its
//! queue/batch/execute/total breakdown, and counters plus latency
//! histograms accumulate in the metric registry. Note that a span's
//! `exec` segment covers the whole supervised call (retries and backoff
//! included), while the `carin_exec_latency_ms` histogram and the
//! report's `latency_ms` record the successful attempt only. Export via
//! [`Telemetry::events_jsonl`] / [`Telemetry::prometheus`].
//!
//! # Memory path
//!
//! The steady-state request path is allocation-free (see ROADMAP
//! "Memory path"): routing moves interned `Copy`
//! [`ArtifactId`](crate::runtime::ArtifactId) handles instead of cloned
//! stem `String`s (display names resolve through
//! [`Router::table`](crate::coordinator::router::RouteTable) only at
//! report/export time), request payloads are `Arc`-backed
//! [`TensorBuf`]s leased from the coordinator's [`BufferPool`] (shared
//! with its batchers), and batch formation concatenates into recycled
//! pool slots. The run's pool traffic is published as the
//! `carin_bufpool_{hits,misses,returns}` counters at the end of each
//! serve.

use std::collections::HashMap;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::batcher::{Batch, Batcher, Formed, Request as BatchRequest};
use crate::coordinator::router::Router;
use crate::device::Engine;
use crate::manager::{Monitor, RuntimeManager};
use crate::moo::Solution;
use crate::runtime::engine::{random_input_pooled, InferenceEngine, Tensor};
use crate::runtime::faults::{fault_kind_of, FaultKind, Inference};
use crate::runtime::{ArtifactId, ArtifactMeta};
use crate::telemetry::{EventKind, Span, Telemetry};
use crate::util::{Backoff, BufPoolStats, BufferPool, Summary, TensorBuf};
use crate::zoo::Registry;

/// One serving request (the synthetic workload generates payloads from
/// the request id, so only routing metadata crosses the channel).
#[derive(Debug)]
pub struct ServeRequest {
    pub task: usize,
    pub id: u64,
    pub submitted: Instant,
    /// Absolute completion deadline derived from the task's SLO; requests
    /// that can no longer meet it are shed at dequeue instead of executed.
    /// `None` disables shedding for this request.
    pub deadline: Option<Instant>,
}

/// Supervision knobs for fault-tolerant serving.
#[derive(Debug, Clone)]
pub struct FaultPolicy {
    /// Total attempts per engine call (1 = no retry).
    pub max_attempts: usize,
    /// First retry delay of the capped exponential backoff.
    pub backoff_base: Duration,
    /// Backoff ceiling.
    pub backoff_cap: Duration,
    /// Consecutive exhausted-retry failures on a task before its routed
    /// engine is reported faulted.
    pub fault_threshold: usize,
    /// Requests between health probes of a faulted route.
    pub probe_interval: usize,
    /// Consecutive probe successes before the fault signal clears.
    pub heal_threshold: usize,
    /// Monitor hysteresis: consecutive observations before a signal flips.
    pub hysteresis_hold: usize,
    /// Watchdog deadline multiplier over the latency SLO: a supervised
    /// call is abandoned after `max(SLO × timeout_mult, timeout_floor)`.
    /// Non-positive disables timeout supervision.
    pub timeout_mult: f64,
    /// Lower bound on the watchdog deadline, so tight SLOs do not turn
    /// ordinary scheduling jitter into timeouts.
    pub timeout_floor: Duration,
}

impl Default for FaultPolicy {
    fn default() -> Self {
        FaultPolicy {
            max_attempts: 3,
            backoff_base: Duration::from_millis(1),
            backoff_cap: Duration::from_millis(16),
            fault_threshold: 2,
            probe_interval: 8,
            heal_threshold: 2,
            hysteresis_hold: 2,
            timeout_mult: 8.0,
            timeout_floor: Duration::from_millis(50),
        }
    }
}

/// The per-call watchdog deadline for the given supervision knobs and
/// latency SLO: `max(SLO × timeout_mult, timeout_floor)`. `None` (no
/// bound) when no SLO is set or the multiplier is non-positive.
pub(crate) fn call_deadline(policy: &FaultPolicy, slo_ms: Option<f64>) -> Option<Duration> {
    let slo = slo_ms?;
    if policy.timeout_mult <= 0.0 || !slo.is_finite() || slo <= 0.0 {
        return None;
    }
    let floor_ms = policy.timeout_floor.as_secs_f64() * 1000.0;
    Some(Duration::from_secs_f64((slo * policy.timeout_mult).max(floor_ms) / 1000.0))
}

/// Per-task serving statistics. See the module docs for the taxonomy.
#[derive(Debug)]
pub struct TaskReport {
    pub task: usize,
    pub artifact: String,
    /// Requests that executed successfully.
    pub completed: usize,
    /// Engine calls that succeeded only after at least one retry.
    pub retried: usize,
    /// The subset of `retried` where a prior attempt hit the watchdog
    /// deadline before the call eventually succeeded.
    pub retried_timeout: usize,
    /// Requests whose retries were exhausted on an engine error.
    pub failed: usize,
    /// Requests whose retries were exhausted with the final attempt
    /// abandoned by the watchdog deadline (disjoint from `failed`).
    pub timed_out: usize,
    /// Requests shed at dequeue because their deadline was unreachable.
    pub shed: usize,
    /// Completed requests that met their deadline (== `completed` when
    /// requests carry no deadline).
    pub deadline_met: usize,
    /// Execution latency; [`Summary::empty`] when nothing completed.
    pub latency_ms: Summary,
    /// Queue + batching + execution (request-to-response), ms, accounted
    /// per request (batched requests use their own enqueue timestamps).
    pub e2e_ms: Summary,
    /// Executions that missed the task's latency SLO (if one is set).
    pub slo_misses: usize,
}

/// End-to-end serving report. See the module docs for the taxonomy.
#[derive(Debug)]
pub struct ServeReport {
    pub tasks: Vec<TaskReport>,
    /// Full serve-loop wall clock (includes pre-admission and drain).
    pub wall_s: f64,
    /// Serving window: first admission → last completion, seconds
    /// (falls back to `wall_s` when nothing was admitted).
    pub window_s: f64,
    pub total_requests: usize,
    /// Completed requests per second over the serving window.
    pub throughput_rps: f64,
    /// Successful-within-deadline requests per second over the serving
    /// window (goodput).
    pub goodput_rps: f64,
    /// Total retried engine calls across tasks.
    pub retried: usize,
    /// Total retried calls with a timed-out prior attempt across tasks.
    pub retried_timeout: usize,
    /// Total failed requests across tasks.
    pub failed: usize,
    /// Total watchdog-timed-out requests across tasks (disjoint from
    /// `failed`; `total_requests + failed + shed + timed_out` covers
    /// every admitted request).
    pub timed_out: usize,
    /// Total shed requests across tasks.
    pub shed: usize,
    /// Design switches taken this run while a signal was raised.
    pub fallback_switches: usize,
    /// Design switches back to the calm design this run.
    pub recovered_switches: usize,
}

/// Mutable per-task accounting while a run is in flight. The pooled
/// coordinator keeps one vector of these per worker and reduces them
/// with [`TaskStats::merge_from`] at report time.
#[derive(Debug, Default)]
pub(crate) struct TaskStats {
    pub(crate) lat: Vec<f64>,
    pub(crate) e2e: Vec<f64>,
    pub(crate) exec_sum_ms: f64,
    pub(crate) completed: usize,
    pub(crate) retried: usize,
    pub(crate) retried_timeout: usize,
    pub(crate) failed: usize,
    pub(crate) timed_out: usize,
    pub(crate) shed: usize,
    pub(crate) deadline_met: usize,
}

impl TaskStats {
    /// Pre-size the latency vectors for an expected request count so the
    /// steady-state push never reallocates (see ROADMAP "Memory path").
    pub(crate) fn with_capacity(n: usize) -> TaskStats {
        TaskStats {
            lat: Vec::with_capacity(n),
            e2e: Vec::with_capacity(n),
            ..TaskStats::default()
        }
    }

    pub(crate) fn mean_exec_ms(&self) -> f64 {
        if self.lat.is_empty() {
            0.0
        } else {
            self.exec_sum_ms / self.lat.len() as f64
        }
    }

    /// Fold another accounting shard for the same task into this one.
    pub(crate) fn merge_from(&mut self, other: &TaskStats) {
        self.lat.extend_from_slice(&other.lat);
        self.e2e.extend_from_slice(&other.e2e);
        self.exec_sum_ms += other.exec_sum_ms;
        self.completed += other.completed;
        self.retried += other.retried;
        self.retried_timeout += other.retried_timeout;
        self.failed += other.failed;
        self.timed_out += other.timed_out;
        self.shed += other.shed;
        self.deadline_met += other.deadline_met;
    }
}

/// Health-probe bookkeeping for one faulted route.
#[derive(Debug)]
struct ProbeState {
    /// The interned route that was failing when the fault was raised.
    route: ArtifactId,
    /// Consecutive successful probes so far.
    ok: usize,
}

/// The serving coordinator: owns the engine, router, batchers and the
/// supervision loop (Runtime Manager + monitor) that keeps serving alive
/// through engine faults.
pub struct ServingCoordinator<E: Inference = InferenceEngine> {
    engine: E,
    router: Router,
    manifest: Vec<ArtifactMeta>,
    /// Per-task batcher for batch>1 artifacts.
    batchers: HashMap<usize, Batcher>,
    n_tasks: usize,
    /// Optional per-execution latency SLO (ms) tracked in the report.
    slo_ms: Option<f64>,
    policy: FaultPolicy,
    monitor: Monitor,
    rm: RuntimeManager,
    /// Consecutive exhausted-retry failures per task.
    consecutive_failures: Vec<usize>,
    /// Engines currently reported faulted, with probe bookkeeping.
    faulted: HashMap<Engine, ProbeState>,
    /// Event recorder + metric registry (see the module docs).
    tel: Telemetry,
    /// Lease pool backing input payloads and batch formation (shared
    /// with the batchers; see the module "Memory path" docs).
    pool: BufferPool,
    /// Capacity hint for per-task stat vectors, so steady-state pushes
    /// never grow them. 0 = no hint.
    expected_requests: usize,
}

impl<E: Inference> ServingCoordinator<E> {
    /// Build a coordinator over any [`Inference`] executor (the real PJRT
    /// engine, a [`crate::runtime::StubEngine`], or either wrapped in a
    /// [`crate::runtime::FaultInjector`] / [`crate::runtime::Watchdog`]).
    /// Compiles and preloads every artifact any design can route to — the
    /// RASS design set is small by construction, so this is the paper's
    /// storage/latency advantage over keeping the full zoo resident.
    ///
    /// Crate-internal: external callers build through
    /// [`super::ServeOptions::build_single`] /
    /// [`super::ServeOptions::build_with_engine`].
    pub(crate) fn with_engine(
        engine: E,
        reg: &Registry,
        solution: &Solution,
        manifest: Vec<ArtifactMeta>,
    ) -> Result<ServingCoordinator<E>> {
        let policy = FaultPolicy::default();
        let router = Router::new(reg, solution, &manifest)?;
        let n_tasks = solution.designs[0].config.assignments.len();
        let monitor = Monitor::new(solution.policy.engines.clone(), policy.hysteresis_hold);
        let rm = RuntimeManager::new(solution.clone());
        let mut coord = ServingCoordinator {
            engine,
            router,
            manifest,
            batchers: HashMap::new(),
            n_tasks,
            slo_ms: None,
            policy,
            monitor,
            rm,
            consecutive_failures: vec![0; n_tasks],
            faulted: HashMap::new(),
            tel: Telemetry::new(crate::telemetry::DEFAULT_EVENT_CAPACITY),
            pool: BufferPool::default(),
            expected_requests: 0,
        };
        let d0 = coord.rm.current_design();
        coord.router.set_design(d0);
        coord.tel.registry.set_gauge("carin_current_design", d0 as f64);
        for idx in coord.router.preload_set() {
            let route = coord.router.table().id(idx);
            let meta = coord.manifest[idx].clone();
            coord.supervised_load(route, &meta)?;
        }
        coord.batchers =
            build_batchers(&coord.manifest, &coord.router, coord.n_tasks, &coord.pool);
        Ok(coord)
    }

    /// Track executions against a latency SLO (ms); misses are reported
    /// per task (the serving-side view of the paper's narrow SLOs).
    /// Also derives the per-call watchdog deadline
    /// (`max(SLO × timeout_mult, timeout_floor)`) and pushes it into the
    /// executor stack.
    pub fn set_latency_slo(&mut self, slo_ms: f64) {
        self.slo_ms = Some(slo_ms);
        self.engine.set_call_deadline(call_deadline(&self.policy, self.slo_ms));
    }

    /// Replace the supervision knobs. Resets the monitor (hysteresis
    /// counters restart) — call between runs, not mid-serve.
    pub fn set_fault_policy(&mut self, policy: FaultPolicy) {
        self.monitor = Monitor::new(
            self.rm.solution.policy.engines.clone(),
            policy.hysteresis_hold,
        );
        self.policy = policy;
        self.engine.set_call_deadline(call_deadline(&self.policy, self.slo_ms));
    }

    pub fn n_tasks(&self) -> usize {
        self.n_tasks
    }

    /// The active supervision knobs.
    pub fn fault_policy(&self) -> &FaultPolicy {
        &self.policy
    }

    /// Manually point the router at a design (benches/ablations; the
    /// supervision loop normally drives this through the RM).
    pub fn set_design(&mut self, design: usize) {
        self.router.set_design(design);
        self.batchers = build_batchers(&self.manifest, &self.router, self.n_tasks, &self.pool);
    }

    /// Replace the lease pool backing inputs and batch formation and
    /// rebuild the batchers over it. [`BufferPool::disabled`] reproduces
    /// the copying baseline for A/B benches — call between runs.
    pub fn set_buffer_pool(&mut self, pool: BufferPool) {
        self.pool = pool;
        self.batchers = build_batchers(&self.manifest, &self.router, self.n_tasks, &self.pool);
    }

    /// Cumulative lease statistics of the coordinator's buffer pool
    /// (sweeps pending returns first so the snapshot is current).
    pub fn buffer_pool_stats(&self) -> BufPoolStats {
        self.pool.sweep_returns();
        self.pool.stats()
    }

    /// Hint how many requests each task will see, so per-task stat
    /// vectors are sized once up front instead of growing mid-run.
    pub fn set_expected_requests(&mut self, per_task: usize) {
        self.expected_requests = per_task;
    }

    pub fn current_design(&self) -> usize {
        self.router.design()
    }

    /// The Runtime Manager driving fault fallback/recovery (switch records
    /// live here).
    pub fn runtime_manager(&self) -> &RuntimeManager {
        &self.rm
    }

    /// The telemetry bundle: event timeline, spans-at-completion and the
    /// metric registry. Use its exporters after (or during) a run.
    pub fn telemetry(&self) -> &Telemetry {
        &self.tel
    }

    /// Mutable telemetry access (resize/clear the recorder, register
    /// custom histograms) — between runs, not mid-serve.
    pub fn telemetry_mut(&mut self) -> &mut Telemetry {
        &mut self.tel
    }

    pub fn engine(&self) -> &E {
        &self.engine
    }

    pub fn engine_mut(&mut self) -> &mut E {
        &mut self.engine
    }

    pub fn loaded_models(&self) -> usize {
        self.engine.loaded_count()
    }

    /// Serve a finite synthetic workload: `requests` arrive over an mpsc
    /// channel (producers run on their own threads); the engine loop
    /// drains it until every producer hangs up. Engine faults never abort
    /// the loop — they are retried, shed around, or routed away from.
    pub fn serve(&mut self, rx: mpsc::Receiver<ServeRequest>) -> Result<ServeReport> {
        let t0 = Instant::now();
        let mut stats: Vec<TaskStats> = (0..self.n_tasks)
            .map(|_| TaskStats::with_capacity(self.expected_requests))
            .collect();
        self.consecutive_failures = vec![0; self.n_tasks];
        self.tel.reset_window();
        let pool0 = self.pool.stats();
        let switches_before = self.rm.switches.len();
        let mut seed = 0u64;
        let mut since_probe = 0usize;

        for req in rx.iter() {
            seed += 1;
            let admitted_at = Instant::now();
            self.tel.note_admit();
            self.tel
                .recorder
                .record(EventKind::Admitted { task: req.task as u32, id: req.id });
            self.tel.registry.inc("carin_requests_admitted_total");

            // age out partial batches first so queued members are not
            // starved past their deadline by a quiet task
            self.flush_due_batches(&mut stats);

            // supervision: debounced fault state -> RM -> router
            self.observe_and_maybe_switch(t0, &mut stats);
            since_probe += 1;
            if !self.faulted.is_empty() && since_probe >= self.policy.probe_interval {
                since_probe = 0;
                self.probe_faulted(seed);
                // a heal may have cleared the signal: recover promptly
                self.observe_and_maybe_switch(t0, &mut stats);
            }

            let t = req.task;

            // deadline-aware admission: shed what cannot finish in time
            if let Some(dl) = req.deadline {
                let est = Duration::from_secs_f64(stats[t].mean_exec_ms() / 1000.0);
                if dl.saturating_duration_since(Instant::now()) < est {
                    stats[t].shed += 1;
                    self.tel.recorder.record(EventKind::Shed { task: t as u32, id: req.id });
                    self.tel.registry.inc("carin_requests_shed_total");
                    continue;
                }
            }

            let meta_idx = self.router.route_index(t);
            let route = self.router.route(t);
            if self.batchers.contains_key(&t) {
                // batched path: one engine call per formed batch
                let sample_len = {
                    let meta = &self.manifest[meta_idx];
                    meta.input.numel() / meta.input.shape[0]
                };
                self.tel.recorder.record(EventKind::Batched { task: t as u32, id: req.id });
                let pushed = self.batchers.get_mut(&t).unwrap().push(BatchRequest {
                    id: req.id,
                    payload: sample_pooled(sample_len, seed, &self.pool),
                    enqueued: req.submitted,
                    admitted: admitted_at,
                    deadline: req.deadline,
                });
                match pushed {
                    Ok(formed) => self.finish_formed(t, route, formed, &mut stats),
                    Err(e) => {
                        // a payload the batcher rejects (shape mismatch)
                        // is a failed request, not a crashed serve loop
                        stats[t].failed += 1;
                        self.tel
                            .recorder
                            .record(EventKind::Failed { task: t as u32, id: req.id });
                        self.tel.registry.inc("carin_requests_failed_total");
                        crate::log_warn!("task {t} request {} rejected: {e}", req.id);
                    }
                }
            } else {
                let input = random_input_pooled(&self.manifest[meta_idx], seed, &self.pool);
                self.execute_one(
                    t,
                    route,
                    &input,
                    req.id,
                    req.submitted,
                    admitted_at,
                    req.deadline,
                    &mut stats,
                );
            }
        }
        // drain partial batches (their members' e2e is accounted normally)
        self.flush_pending(&mut stats);

        // publish the run's pool traffic (returns are observed lazily on
        // lease sweeps, so force one before the snapshot)
        self.pool.sweep_returns();
        let ps = self.pool.stats();
        let r = &mut self.tel.registry;
        r.add("carin_bufpool_hits", ps.hits - pool0.hits);
        r.add("carin_bufpool_misses", ps.misses - pool0.misses);
        r.add("carin_bufpool_returns", ps.returns - pool0.returns);

        let wall_s = t0.elapsed().as_secs_f64();
        // throughput/goodput are over the serving window, not the loop's
        // wall clock: channel setup and drain time belong to the harness,
        // not the served requests.
        let window_s = self.tel.window_s().unwrap_or(wall_s).max(1e-9);
        if let Some((a, b)) = self.tel.window_ns() {
            self.tel.registry.set_gauge("carin_window_start_s", a as f64 / 1e9);
            self.tel.registry.set_gauge("carin_window_end_s", b as f64 / 1e9);
        }
        self.tel.registry.set_gauge("carin_window_s", window_s);
        let total: usize = stats.iter().map(|s| s.completed).sum();
        let met: usize = stats.iter().map(|s| s.deadline_met).sum();
        let switches = &self.rm.switches[switches_before..];
        let fallback_switches = switches.iter().filter(|s| !s.state.is_calm()).count();
        let recovered_switches = switches.iter().filter(|s| s.state.is_calm()).count();
        let tasks = (0..self.n_tasks)
            .map(|t| {
                let st = &stats[t];
                TaskReport {
                    task: t,
                    artifact: self.manifest[self.router.route_index(t)].stem.clone(),
                    completed: st.completed,
                    retried: st.retried,
                    retried_timeout: st.retried_timeout,
                    failed: st.failed,
                    timed_out: st.timed_out,
                    shed: st.shed,
                    deadline_met: st.deadline_met,
                    slo_misses: match self.slo_ms {
                        Some(slo) => st.lat.iter().filter(|&&x| x > slo).count(),
                        None => 0,
                    },
                    latency_ms: Summary::of_or_empty(&st.lat),
                    e2e_ms: Summary::of_or_empty(&st.e2e),
                }
            })
            .collect();
        Ok(ServeReport {
            tasks,
            wall_s,
            window_s,
            total_requests: total,
            throughput_rps: total as f64 / window_s,
            goodput_rps: met as f64 / window_s,
            retried: stats.iter().map(|s| s.retried).sum(),
            retried_timeout: stats.iter().map(|s| s.retried_timeout).sum(),
            failed: stats.iter().map(|s| s.failed).sum(),
            timed_out: stats.iter().map(|s| s.timed_out).sum(),
            shed: stats.iter().map(|s| s.shed).sum(),
            fallback_switches,
            recovered_switches,
        })
    }

    /// One supervised engine call: retry with capped exponential backoff.
    /// Watchdog timeouts retry like any other failure (each one counted
    /// in `carin_engine_timeouts_total`); a success after a timed-out
    /// attempt is additionally counted `retried_timeout`. Returns the
    /// successful attempt's execution latency (ms).
    fn supervised_infer(
        &mut self,
        t: usize,
        route: ArtifactId,
        input: &Tensor,
        st: &mut TaskStats,
    ) -> Result<f64> {
        let mut backoff = Backoff::new(self.policy.backoff_base, self.policy.backoff_cap);
        let mut attempt = 0usize;
        let mut timed_out_attempts = 0usize;
        loop {
            attempt += 1;
            let te = Instant::now();
            match self.engine.infer(route, input) {
                Ok(_) => {
                    if attempt > 1 {
                        st.retried += 1;
                        if timed_out_attempts > 0 {
                            st.retried_timeout += 1;
                            self.tel.registry.inc("carin_requests_retried_timeout_total");
                        }
                        self.tel.recorder.record(EventKind::Retried {
                            task: t as u32,
                            attempts: attempt as u32,
                        });
                        self.tel.registry.inc("carin_requests_retried_total");
                    }
                    self.consecutive_failures[t] = 0;
                    return Ok(te.elapsed().as_secs_f64() * 1000.0);
                }
                Err(e) => {
                    if fault_kind_of(&e) == Some(FaultKind::Timeout) {
                        timed_out_attempts += 1;
                        self.tel.registry.inc("carin_engine_timeouts_total");
                    }
                    if attempt >= self.policy.max_attempts {
                        return Err(e);
                    }
                    std::thread::sleep(backoff.next_delay());
                }
            }
        }
    }

    /// Retrying model load (transient load faults are part of the fault
    /// model; a persistent failure propagates).
    fn supervised_load(&mut self, route: ArtifactId, meta: &ArtifactMeta) -> Result<()> {
        let mut backoff = Backoff::new(self.policy.backoff_base, self.policy.backoff_cap);
        let mut attempt = 0usize;
        loop {
            attempt += 1;
            match self.engine.load(route, meta) {
                Ok(()) => return Ok(()),
                Err(e) => {
                    if attempt >= self.policy.max_attempts {
                        return Err(e);
                    }
                    std::thread::sleep(backoff.next_delay());
                }
            }
        }
    }

    /// Registry + recorder bookkeeping for one completed request.
    /// `exec_ms` is the successful attempt's engine latency; the span's
    /// exec segment additionally covers retries and backoff.
    fn note_completion(&mut self, span: &Span, exec_ms: f64, met: bool) {
        span.record(&mut self.tel.recorder, met);
        self.tel.note_done();
        let r = &mut self.tel.registry;
        r.inc("carin_requests_completed_total");
        if met {
            r.inc("carin_requests_deadline_met_total");
        }
        r.observe("carin_exec_latency_ms", exec_ms);
        r.observe("carin_e2e_latency_ms", span.total_ms());
        r.observe("carin_queue_latency_ms", span.queue_ms());
        r.observe("carin_batch_wait_ms", span.batch_ms());
    }

    /// Shed + execute the outcome of one batch-formation attempt.
    fn finish_formed(
        &mut self,
        t: usize,
        route: ArtifactId,
        formed: Formed,
        stats: &mut [TaskStats],
    ) {
        for r in &formed.shed {
            stats[t].shed += 1;
            self.tel.recorder.record(EventKind::Shed { task: t as u32, id: r.id });
            self.tel.registry.inc("carin_requests_shed_total");
        }
        if let Some(batch) = formed.batch {
            self.execute_batch(t, route, batch, stats);
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn execute_one(
        &mut self,
        t: usize,
        route: ArtifactId,
        input: &Tensor,
        id: u64,
        submitted: Instant,
        admitted: Instant,
        deadline: Option<Instant>,
        stats: &mut [TaskStats],
    ) {
        let dispatched = Instant::now();
        self.tel.recorder.record(EventKind::Dispatched {
            task: t as u32,
            route: route.0,
            occupancy: 1,
        });
        self.tel.registry.inc("carin_engine_dispatch_total");
        match self.supervised_infer(t, route, input, &mut stats[t]) {
            Ok(exec_ms) => {
                let done = Instant::now();
                let met = match deadline {
                    Some(dl) => done <= dl,
                    None => true,
                };
                {
                    let st = &mut stats[t];
                    st.lat.push(exec_ms);
                    st.exec_sum_ms += exec_ms;
                    st.e2e.push(done.duration_since(submitted).as_secs_f64() * 1000.0);
                    st.completed += 1;
                    if met {
                        st.deadline_met += 1;
                    }
                }
                let span =
                    Span { task: t, id, submitted, admitted, dispatched, completed: done };
                self.note_completion(&span, exec_ms, met);
            }
            Err(e) => {
                if fault_kind_of(&e) == Some(FaultKind::Timeout) {
                    stats[t].timed_out += 1;
                    let span = Span {
                        task: t,
                        id,
                        submitted,
                        admitted,
                        dispatched,
                        completed: Instant::now(),
                    };
                    let d = call_deadline(&self.policy, self.slo_ms).unwrap_or_default();
                    span.record_timeout(&mut self.tel.recorder, d);
                    self.tel.registry.inc("carin_requests_timed_out_total");
                } else {
                    stats[t].failed += 1;
                    self.tel.recorder.record(EventKind::Failed { task: t as u32, id });
                    self.tel.registry.inc("carin_requests_failed_total");
                }
                self.note_failure(t);
            }
        }
    }

    fn execute_batch(&mut self, t: usize, route: ArtifactId, batch: Batch, stats: &mut [TaskStats]) {
        let Batch { ids, payload, occupancy, enqueued, admitted, deadlines } = batch;
        let input = Tensor::F32(payload);
        let dispatched = Instant::now();
        self.tel.recorder.record(EventKind::Dispatched {
            task: t as u32,
            route: route.0,
            occupancy: occupancy as u32,
        });
        self.tel.registry.inc("carin_engine_dispatch_total");
        match self.supervised_infer(t, route, &input, &mut stats[t]) {
            Ok(exec_ms) => {
                let done = Instant::now();
                for i in 0..occupancy {
                    let met = match deadlines[i] {
                        Some(dl) => done <= dl,
                        None => true,
                    };
                    {
                        let st = &mut stats[t];
                        st.lat.push(exec_ms);
                        st.exec_sum_ms += exec_ms;
                        // each member's own enqueue timestamp, not the batch
                        // trigger's: queue time is part of its e2e.
                        st.e2e.push(done.duration_since(enqueued[i]).as_secs_f64() * 1000.0);
                        st.completed += 1;
                        if met {
                            st.deadline_met += 1;
                        }
                    }
                    let span = Span {
                        task: t,
                        id: ids[i],
                        submitted: enqueued[i],
                        admitted: admitted[i],
                        dispatched,
                        completed: done,
                    };
                    self.note_completion(&span, exec_ms, met);
                }
            }
            Err(e) => {
                if fault_kind_of(&e) == Some(FaultKind::Timeout) {
                    stats[t].timed_out += occupancy;
                    let now = Instant::now();
                    let d = call_deadline(&self.policy, self.slo_ms).unwrap_or_default();
                    for i in 0..occupancy {
                        let span = Span {
                            task: t,
                            id: ids[i],
                            submitted: enqueued[i],
                            admitted: admitted[i],
                            dispatched,
                            completed: now,
                        };
                        span.record_timeout(&mut self.tel.recorder, d);
                        self.tel.registry.inc("carin_requests_timed_out_total");
                    }
                } else {
                    stats[t].failed += occupancy;
                    for &id in ids.iter().take(occupancy) {
                        self.tel.recorder.record(EventKind::Failed { task: t as u32, id });
                        self.tel.registry.inc("carin_requests_failed_total");
                    }
                }
                self.note_failure(t);
            }
        }
    }

    /// Exhausted-retry failure: raise the fault signal for the engine
    /// carrying this task's route once the threshold is crossed.
    fn note_failure(&mut self, t: usize) {
        self.consecutive_failures[t] += 1;
        if self.consecutive_failures[t] >= self.policy.fault_threshold {
            let e = self.engine_of(t);
            let route = self.router.route(t);
            self.monitor.report_fault(e, true);
            if !self.faulted.contains_key(&e) {
                crate::log_warn!(
                    "fault raised on {} after {} consecutive failures (task {t}, route {})",
                    e.name(),
                    self.consecutive_failures[t],
                    self.router.table().name(route)
                );
                self.faulted.insert(e, ProbeState { route, ok: 0 });
                self.tel.recorder.record(EventKind::FaultRaised {
                    engine: e.index() as u8,
                    task: t as u32,
                });
                self.tel.registry.inc("carin_faults_raised_total");
            }
            self.tel
                .registry
                .set_gauge("carin_fault_raw_mask", self.monitor.raw_fault_mask() as f64);
        }
    }

    /// The modeled engine serving task `t` under the current design.
    fn engine_of(&self, t: usize) -> Engine {
        self.rm.solution.designs[self.router.design()].config.assignments[t]
            .proc
            .engine()
    }

    /// Advance the monitor and let the RM fall back / recover. A switch
    /// is mirrored into the telemetry timeline as the audit-trail event.
    fn observe_and_maybe_switch(&mut self, t0: Instant, stats: &mut [TaskStats]) {
        let state = self.monitor.tick();
        if let Some(d) = self.rm.observe(state, t0.elapsed().as_secs_f64()) {
            if let Some(rec) = self.rm.switches.last() {
                let fallback = !rec.state.is_calm();
                crate::log_info!(
                    "{} switch d[{}] -> d[{}] (bad_mask {:#04b}, {} ns decision)",
                    if fallback { "fallback" } else { "recovery" },
                    rec.from,
                    rec.to,
                    rec.bad_mask,
                    rec.decision_ns
                );
                self.tel.recorder.record(EventKind::Switch {
                    from: rec.from as u32,
                    to: rec.to as u32,
                    troubled: rec.state.troubled,
                    faulted: rec.state.faulted,
                    memory: rec.state.memory,
                    bad_mask: rec.bad_mask,
                    decision_ns: rec.decision_ns as u64,
                    fallback,
                });
                let name = if fallback {
                    "carin_switches_fallback_total"
                } else {
                    "carin_switches_recovery_total"
                };
                let decision_ns = rec.decision_ns as f64;
                let r = &mut self.tel.registry;
                r.inc(name);
                r.observe("carin_switch_decision_ns", decision_ns);
                r.set_gauge("carin_current_design", d as f64);
            }
            self.apply_switch(d, stats);
        }
    }

    /// Route to a new design: flush in-flight batches through the old
    /// routes, repoint the router, make sure the new routes are resident
    /// and rebuild the batchers for the new artifact shapes.
    fn apply_switch(&mut self, design: usize, stats: &mut [TaskStats]) {
        self.flush_pending(stats);
        self.router.set_design(design);
        for t in 0..self.n_tasks {
            let idx = self.router.route_index(t);
            let route = self.router.table().id(idx);
            if !self.engine.is_loaded(route) {
                let meta = self.manifest[idx].clone();
                // a failed load leaves the route cold: requests on it will
                // fail supervision and re-raise the fault signal, so the
                // policy moves on rather than the process dying here.
                let _ = self.supervised_load(route, &meta);
            }
        }
        self.batchers = build_batchers(&self.manifest, &self.router, self.n_tasks, &self.pool);
    }

    /// Flush partial batches whose oldest member exceeded the batching
    /// deadline; flushed members get full latency/e2e accounting (and
    /// expired members are shed, see [`Formed::shed`]).
    fn flush_due_batches(&mut self, stats: &mut [TaskStats]) {
        let now = Instant::now();
        for t in 0..self.n_tasks {
            let maybe = self.batchers.get_mut(&t).map(|b| b.flush_due(now));
            if let Some(formed) = maybe {
                let route = self.router.route(t);
                self.finish_formed(t, route, formed, stats);
            }
        }
    }

    /// Execute every pending partial batch through its current route.
    fn flush_pending(&mut self, stats: &mut [TaskStats]) {
        for t in 0..self.n_tasks {
            let maybe = self.batchers.get_mut(&t).map(|b| b.flush());
            if let Some(formed) = maybe {
                let route = self.router.route(t);
                self.finish_formed(t, route, formed, stats);
            }
        }
    }

    /// Health-probe every faulted route off the request path; clear the
    /// fault signal after `heal_threshold` consecutive successes.
    fn probe_faulted(&mut self, seed: u64) {
        let targets: Vec<(Engine, ArtifactId)> =
            self.faulted.iter().map(|(e, p)| (*e, p.route)).collect();
        for (e, route) in targets {
            let input = random_input_pooled(&self.manifest[route.index()], seed, &self.pool);
            let healthy = self.engine.infer(route, &input).is_ok();
            self.tel
                .recorder
                .record(EventKind::Probe { engine: e.index() as u8, ok: healthy });
            self.tel.registry.inc("carin_probes_total");
            let mut healed = false;
            if let Some(p) = self.faulted.get_mut(&e) {
                if healthy {
                    p.ok += 1;
                    healed = p.ok >= self.policy.heal_threshold;
                } else {
                    p.ok = 0;
                }
            }
            if healed {
                crate::log_info!("fault cleared on {} after consecutive probe successes", e.name());
                self.monitor.report_fault(e, false);
                self.faulted.remove(&e);
                self.tel
                    .recorder
                    .record(EventKind::FaultCleared { engine: e.index() as u8 });
                self.tel.registry.inc("carin_faults_cleared_total");
                self.tel
                    .registry
                    .set_gauge("carin_fault_raw_mask", self.monitor.raw_fault_mask() as f64);
            }
        }
    }
}

pub(crate) fn build_batchers(
    manifest: &[ArtifactMeta],
    router: &Router,
    n_tasks: usize,
    pool: &BufferPool,
) -> HashMap<usize, Batcher> {
    let routes: Vec<(usize, usize)> = (0..n_tasks).map(|t| (t, router.route_index(t))).collect();
    build_batchers_for(manifest, &routes, pool)
}

/// Batchers for an explicit (task, manifest index) route list — the
/// pooled workers' form, which needs no router instance. All batchers
/// form their batches out of the given shared lease pool.
pub(crate) fn build_batchers_for(
    manifest: &[ArtifactMeta],
    routes: &[(usize, usize)],
    pool: &BufferPool,
) -> HashMap<usize, Batcher> {
    let mut batchers = HashMap::new();
    for &(t, idx) in routes {
        let meta = &manifest[idx];
        // a leading batch dimension only exists on rank-4 NHWC image
        // inputs (UC4's face crops); 1-D waveforms and token sequences
        // are single-sample.
        let batch = if meta.input.shape.len() == 4 { meta.input.shape[0] } else { 1 };
        if meta.input.dtype == crate::runtime::DType::F32 && batch > 1 {
            let sample_len = meta.input.numel() / batch;
            batchers.insert(
                t,
                Batcher::with_pool(batch, sample_len, Duration::from_millis(5), pool.clone()),
            );
        }
    }
    batchers
}

/// One flat f32 sample drawn into a pooled lease (the zero-copy
/// counterpart of collecting into a fresh `Vec`).
pub(crate) fn sample_pooled(len: usize, seed: u64, pool: &BufferPool) -> TensorBuf {
    let mut rng = crate::util::Rng::new(seed);
    pool.lease_with(len, |v| v.extend((0..len).map(|_| rng.normal() as f32)))
}
