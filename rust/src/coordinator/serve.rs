//! Serving front-end over the real PJRT engine: workload threads feed a
//! request channel; the engine loop (PJRT types are not `Send`, so the
//! engine lives on its owning thread) routes each request through the
//! Runtime-Manager-selected design, batches where the model expects a
//! batch, executes, and reports per-request latency.

use std::collections::HashMap;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::batcher::{Batcher, Request as BatchRequest};
use crate::coordinator::router::Router;
use crate::moo::Solution;
use crate::runtime::engine::{random_input, InferenceEngine, Tensor};
use crate::runtime::ArtifactMeta;
use crate::util::Summary;
use crate::zoo::Registry;

/// One serving request (payload generated if `None` — synthetic workload).
#[derive(Debug)]
pub struct ServeRequest {
    pub task: usize,
    pub id: u64,
    pub submitted: Instant,
}

/// Per-task serving statistics.
#[derive(Debug)]
pub struct TaskReport {
    pub task: usize,
    pub artifact: String,
    pub completed: usize,
    pub latency_ms: Summary,
    /// Queue + batching + execution (request-to-response), ms.
    pub e2e_ms: Summary,
    /// Executions that missed the task's latency SLO (if one is set).
    pub slo_misses: usize,
}

/// End-to-end serving report.
#[derive(Debug)]
pub struct ServeReport {
    pub tasks: Vec<TaskReport>,
    pub wall_s: f64,
    pub total_requests: usize,
    /// Requests per second across tasks.
    pub throughput_rps: f64,
}

/// The serving coordinator: owns the engine, router and batchers.
pub struct ServingCoordinator {
    engine: InferenceEngine,
    router: Router,
    manifest: Vec<ArtifactMeta>,
    /// Per-task batcher for batch>1 artifacts.
    batchers: HashMap<usize, Batcher>,
    n_tasks: usize,
    /// Optional per-execution latency SLO (ms) tracked in the report.
    slo_ms: Option<f64>,
}

impl ServingCoordinator {
    /// Compile and preload every artifact any design can route to — the
    /// RASS design set is small by construction, so this is the paper's
    /// storage/latency advantage over keeping the full zoo resident.
    pub fn new(
        reg: &Registry,
        solution: &Solution,
        manifest: Vec<ArtifactMeta>,
    ) -> Result<ServingCoordinator> {
        let mut engine = InferenceEngine::cpu()?;
        let router = Router::new(reg, solution, &manifest)?;
        for idx in router.preload_set() {
            engine.load(&manifest[idx])?;
        }
        let n_tasks = solution.designs[0].config.assignments.len();
        let mut batchers = HashMap::new();
        for t in 0..n_tasks {
            let meta = &manifest[router.route_index(t)];
            // a leading batch dimension only exists on rank-4 NHWC image
            // inputs (UC4's face crops); 1-D waveforms and token sequences
            // are single-sample.
            let batch = if meta.input.shape.len() == 4 { meta.input.shape[0] } else { 1 };
            if meta.input.dtype == crate::runtime::DType::F32 && batch > 1 {
                let sample_len = meta.input.numel() / batch;
                batchers.insert(
                    t,
                    Batcher::new(batch, sample_len, Duration::from_millis(5)),
                );
            }
        }
        Ok(ServingCoordinator { engine, router, manifest, batchers, n_tasks, slo_ms: None })
    }

    /// Track executions against a latency SLO (ms); misses are reported
    /// per task (the serving-side view of the paper's narrow SLOs).
    pub fn set_latency_slo(&mut self, slo_ms: f64) {
        self.slo_ms = Some(slo_ms);
    }

    pub fn n_tasks(&self) -> usize {
        self.n_tasks
    }

    pub fn set_design(&mut self, design: usize) {
        self.router.set_design(design);
    }

    pub fn loaded_models(&self) -> usize {
        self.engine.loaded().len()
    }

    /// Serve a finite synthetic workload: `requests` arrive over an mpsc
    /// channel (producers run on their own threads); the engine loop
    /// drains it until every producer hangs up.
    pub fn serve(&mut self, rx: mpsc::Receiver<ServeRequest>) -> Result<ServeReport> {
        let t0 = Instant::now();
        let mut lat: Vec<Vec<f64>> = vec![Vec::new(); self.n_tasks];
        let mut e2e: Vec<Vec<f64>> = vec![Vec::new(); self.n_tasks];
        let mut completed = vec![0usize; self.n_tasks];
        let mut seed = 0u64;

        for req in rx.iter() {
            seed += 1;
            let t = req.task;
            let meta_idx = self.router.route_index(t);
            let meta = &self.manifest[meta_idx];
            if let Some(b) = self.batchers.get_mut(&t) {
                // batched path: one engine call per formed batch
                let sample_len = meta.input.numel() / meta.input.shape[0];
                let maybe = b.push(BatchRequest {
                    id: req.id,
                    payload: vec_sample(sample_len, seed),
                    enqueued: req.submitted,
                });
                if let Some(batch) = maybe {
                    let te = Instant::now();
                    self.engine.infer(&meta.stem.clone(), &Tensor::F32(batch.payload))?;
                    let exec_ms = te.elapsed().as_secs_f64() * 1000.0;
                    for _ in 0..batch.occupancy {
                        lat[t].push(exec_ms);
                        completed[t] += 1;
                    }
                    e2e[t].push(req.submitted.elapsed().as_secs_f64() * 1000.0);
                }
            } else {
                let input = random_input(meta, seed);
                let te = Instant::now();
                self.engine.infer(&meta.stem.clone(), &input)?;
                lat[t].push(te.elapsed().as_secs_f64() * 1000.0);
                e2e[t].push(req.submitted.elapsed().as_secs_f64() * 1000.0);
                completed[t] += 1;
            }
        }
        // drain partial batches
        for (t, b) in self.batchers.iter_mut() {
            if let Some(batch) = b.flush() {
                let meta = &self.manifest[self.router.route_index(*t)];
                let te = Instant::now();
                self.engine.infer(&meta.stem.clone(), &Tensor::F32(batch.payload))?;
                let exec_ms = te.elapsed().as_secs_f64() * 1000.0;
                for _ in 0..batch.occupancy {
                    lat[*t].push(exec_ms);
                    completed[*t] += 1;
                }
            }
        }

        let wall_s = t0.elapsed().as_secs_f64();
        let total: usize = completed.iter().sum();
        let tasks = (0..self.n_tasks)
            .map(|t| TaskReport {
                task: t,
                artifact: self.manifest[self.router.route_index(t)].stem.clone(),
                completed: completed[t],
                slo_misses: match self.slo_ms {
                    Some(slo) => lat[t].iter().filter(|&&x| x > slo).count(),
                    None => 0,
                },
                latency_ms: Summary::of(if lat[t].is_empty() { &[0.0] } else { &lat[t] }),
                e2e_ms: Summary::of(if e2e[t].is_empty() { &[0.0] } else { &e2e[t] }),
            })
            .collect();
        Ok(ServeReport {
            tasks,
            wall_s,
            total_requests: total,
            throughput_rps: total as f64 / wall_s,
        })
    }
}

fn vec_sample(len: usize, seed: u64) -> Vec<f32> {
    let mut rng = crate::util::Rng::new(seed);
    (0..len).map(|_| rng.normal() as f32).collect()
}
