//! The unified coordinator API: one object-safe trait over both serving
//! coordinators and one builder that constructs them.
//!
//! # Why a trait
//!
//! The single-loop [`ServingCoordinator`] and the per-engine
//! [`PooledCoordinator`] implement the same serving contract — admit a
//! finite workload, supervise engine calls (retry, shed, watchdog
//! timeout, fault fallback/recovery) and return a [`ServeReport`] whose
//! taxonomy satisfies `completed + failed + shed + timed_out ==
//! submitted`. [`Coordinator`] captures that contract so front-ends
//! (the CLI, benches, conformance tests) can pick an implementation at
//! runtime through `&mut dyn Coordinator` instead of duplicating every
//! call site per coordinator.
//!
//! # Why a builder
//!
//! The positional constructors grew incompatible shapes
//! (`ServingCoordinator::new(reg, sol, manifest)` vs
//! `PooledCoordinator::new(factory, reg, sol, manifest)`) and every
//! knob (fault policy, SLO, watchdog multiplier, telemetry sizing)
//! needed post-construction setter calls in the right order.
//! [`ServeOptions`] is the one configuration bag: chain the knobs, then
//! call a `build_*` terminal for the coordinator flavour you want. One
//! options value can build several coordinators (that is what the
//! conformance test does), so the terminals take `&self`.
//!
//! # Migration
//!
//! The positional constructors are crate-private since the watchdog PR:
//!
//! ```text
//! // before
//! let mut c = ServingCoordinator::with_engine(engine, &reg, &sol, manifest)?;
//! c.set_fault_policy(policy);
//! c.set_latency_slo(42.0);
//! // after
//! let mut c = ServeOptions::new()
//!     .fault_policy(policy)
//!     .latency_slo_ms(42.0)
//!     .build_with_engine(engine, &reg, &sol, manifest)?;
//! ```
//!
//! `build_single` replaces `ServingCoordinator::new` (PJRT CPU engine),
//! `build_with_engine` replaces `ServingCoordinator::with_engine`, and
//! `build_pooled` replaces `PooledCoordinator::new`.

use std::path::PathBuf;
use std::sync::mpsc;
use std::time::Duration;

use anyhow::Result;

use crate::coordinator::pool::PooledCoordinator;
use crate::coordinator::serve::{FaultPolicy, ServeReport, ServeRequest, ServingCoordinator};
use crate::device::Engine;
use crate::error::CarinError;
use crate::moo::Solution;
use crate::runtime::engine::InferenceEngine;
use crate::runtime::faults::Inference;
use crate::runtime::ArtifactMeta;
use crate::telemetry::{Recorder, Telemetry};
use crate::zoo::Registry;

/// The serving contract shared by both coordinators. Object-safe: the
/// CLI serves through `&mut dyn Coordinator`, chosen by `--pooled`.
pub trait Coordinator {
    /// Drain a finite workload from `rx` until every producer hangs up
    /// and return the aggregated report. The report taxonomy is closed:
    /// `completed + failed + shed + timed_out == submitted` and
    /// `goodput_rps <= throughput_rps`.
    fn serve(&mut self, rx: mpsc::Receiver<ServeRequest>) -> Result<ServeReport>;

    /// Track executions against a latency SLO (ms) and derive the
    /// per-call watchdog deadline from it (see
    /// [`FaultPolicy::timeout_mult`]).
    fn set_latency_slo(&mut self, slo_ms: f64);

    /// Replace the supervision knobs. Resets the monitor — call between
    /// runs, not mid-serve.
    fn set_fault_policy(&mut self, policy: FaultPolicy);

    /// The design the router currently serves under.
    fn current_design(&self) -> usize;

    /// The telemetry bundle of the last (or in-progress) run.
    fn telemetry(&self) -> &Telemetry;
}

impl<E: Inference> Coordinator for ServingCoordinator<E> {
    fn serve(&mut self, rx: mpsc::Receiver<ServeRequest>) -> Result<ServeReport> {
        ServingCoordinator::serve(self, rx)
    }

    fn set_latency_slo(&mut self, slo_ms: f64) {
        ServingCoordinator::set_latency_slo(self, slo_ms);
    }

    fn set_fault_policy(&mut self, policy: FaultPolicy) {
        ServingCoordinator::set_fault_policy(self, policy);
    }

    fn current_design(&self) -> usize {
        ServingCoordinator::current_design(self)
    }

    fn telemetry(&self) -> &Telemetry {
        ServingCoordinator::telemetry(self)
    }
}

impl<E, F> Coordinator for PooledCoordinator<E, F>
where
    E: Inference,
    F: Fn(Engine) -> Result<E> + Sync,
{
    fn serve(&mut self, rx: mpsc::Receiver<ServeRequest>) -> Result<ServeReport> {
        PooledCoordinator::serve(self, rx)
    }

    fn set_latency_slo(&mut self, slo_ms: f64) {
        PooledCoordinator::set_latency_slo(self, slo_ms);
    }

    fn set_fault_policy(&mut self, policy: FaultPolicy) {
        PooledCoordinator::set_fault_policy(self, policy);
    }

    fn current_design(&self) -> usize {
        PooledCoordinator::current_design(self)
    }

    fn telemetry(&self) -> &Telemetry {
        PooledCoordinator::telemetry(self)
    }
}

/// Builder for both coordinator flavours: collect the serving knobs,
/// then call one `build_*` terminal. See the module docs for the
/// migration from the positional constructors.
#[derive(Debug, Clone, Default)]
pub struct ServeOptions {
    policy: FaultPolicy,
    slo_ms: Option<f64>,
    event_capacity: Option<usize>,
    telemetry_path: Option<PathBuf>,
    /// Buffer-pool slot cap for the single-loop coordinator (`Some(0)`
    /// disables pooling — the copying baseline for A/B benches).
    pool_slots: Option<usize>,
    /// Per-task request-count hint: pre-sizes stat vectors so the
    /// steady-state path never grows them.
    expected_requests: Option<usize>,
}

impl ServeOptions {
    pub fn new() -> ServeOptions {
        ServeOptions::default()
    }

    /// Replace the whole supervision policy (retry, backoff, fault and
    /// watchdog knobs). Later [`ServeOptions::timeout_mult`] /
    /// [`ServeOptions::timeout_floor`] calls edit this policy in place.
    pub fn fault_policy(mut self, policy: FaultPolicy) -> ServeOptions {
        self.policy = policy;
        self
    }

    /// Track executions against a latency SLO (ms). Also the base of
    /// the per-call watchdog deadline:
    /// `max(SLO × timeout_mult, timeout_floor)`.
    pub fn latency_slo_ms(mut self, slo_ms: f64) -> ServeOptions {
        self.slo_ms = Some(slo_ms);
        self
    }

    /// Watchdog deadline multiplier over the SLO (non-positive disables
    /// timeout supervision).
    pub fn timeout_mult(mut self, mult: f64) -> ServeOptions {
        self.policy.timeout_mult = mult;
        self
    }

    /// Lower bound on the watchdog deadline.
    pub fn timeout_floor(mut self, floor: Duration) -> ServeOptions {
        self.policy.timeout_floor = floor;
        self
    }

    /// Size of the telemetry event ring buffer (defaults to
    /// [`crate::telemetry::DEFAULT_EVENT_CAPACITY`]).
    pub fn event_capacity(mut self, events: usize) -> ServeOptions {
        self.event_capacity = Some(events);
        self
    }

    /// Dump telemetry after the run (see
    /// [`ServeOptions::dump_telemetry`]): the event timeline as
    /// JSON-lines to `path` and a Prometheus snapshot to `path.prom`.
    pub fn telemetry_path(mut self, path: impl Into<PathBuf>) -> ServeOptions {
        self.telemetry_path = Some(path.into());
        self
    }

    /// Optional-flavoured [`ServeOptions::telemetry_path`] for CLI
    /// plumbing (`None` leaves the destination unset).
    pub fn telemetry_path_opt(mut self, path: Option<PathBuf>) -> ServeOptions {
        self.telemetry_path = path;
        self
    }

    /// Cap the single-loop coordinator's [`crate::util::BufferPool`] at
    /// `slots` recycled buffers. `0` disables pooling entirely — every
    /// lease allocates, reproducing the copying baseline for A/B
    /// benches. Unset = the pool default
    /// ([`crate::util::bufpool::DEFAULT_POOL_SLOTS`]).
    pub fn pool_slots(mut self, slots: usize) -> ServeOptions {
        self.pool_slots = Some(slots);
        self
    }

    /// Hint how many requests each task will see, so per-task stat
    /// vectors are sized once up front instead of growing mid-run (part
    /// of the zero-allocation steady state, see ROADMAP "Memory path").
    pub fn expected_requests(mut self, per_task: usize) -> ServeOptions {
        self.expected_requests = Some(per_task);
        self
    }

    /// Build the single-loop coordinator over the default PJRT CPU
    /// engine (replaces `ServingCoordinator::new`).
    pub fn build_single(
        &self,
        reg: &Registry,
        solution: &Solution,
        manifest: Vec<ArtifactMeta>,
    ) -> Result<ServingCoordinator<InferenceEngine>> {
        self.build_with_engine(InferenceEngine::cpu()?, reg, solution, manifest)
    }

    /// Build the single-loop coordinator over any [`Inference`] executor
    /// (replaces `ServingCoordinator::with_engine`).
    pub fn build_with_engine<E: Inference>(
        &self,
        engine: E,
        reg: &Registry,
        solution: &Solution,
        manifest: Vec<ArtifactMeta>,
    ) -> Result<ServingCoordinator<E>> {
        let mut coord = ServingCoordinator::with_engine(engine, reg, solution, manifest)?;
        self.apply(&mut coord);
        if let Some(cap) = self.event_capacity {
            let epoch = coord.telemetry().recorder.epoch();
            coord.telemetry_mut().recorder = Recorder::with_epoch(cap, epoch);
        }
        if let Some(slots) = self.pool_slots {
            coord.set_buffer_pool(crate::util::BufferPool::new(slots));
        }
        if let Some(n) = self.expected_requests {
            coord.set_expected_requests(n);
        }
        Ok(coord)
    }

    /// Build the per-engine worker pool coordinator (replaces
    /// `PooledCoordinator::new`). `factory` runs once inside each worker
    /// thread to construct that worker's engine.
    pub fn build_pooled<E, F>(
        &self,
        factory: F,
        reg: &Registry,
        solution: &Solution,
        manifest: Vec<ArtifactMeta>,
    ) -> Result<PooledCoordinator<E, F>>
    where
        E: Inference,
        F: Fn(Engine) -> Result<E> + Sync,
    {
        let mut coord = PooledCoordinator::new(factory, reg, solution, manifest)?;
        self.apply(&mut coord);
        if let Some(cap) = self.event_capacity {
            let epoch = coord.telemetry().recorder.epoch();
            coord.telemetry_mut().recorder = Recorder::with_epoch(cap, epoch);
        }
        Ok(coord)
    }

    fn apply(&self, coord: &mut dyn Coordinator) {
        coord.set_fault_policy(self.policy.clone());
        if let Some(slo) = self.slo_ms {
            coord.set_latency_slo(slo);
        }
    }

    /// Write the run's telemetry to the configured destination: the
    /// event timeline as JSON-lines to the path, the Prometheus
    /// snapshot to `<path>.prom`. A no-op returning `Ok(None)` when no
    /// path was set; otherwise returns the events path written.
    pub fn dump_telemetry(&self, tel: &Telemetry) -> Result<Option<PathBuf>> {
        let Some(path) = &self.telemetry_path else {
            return Ok(None);
        };
        let write = |p: &std::path::Path, body: String| -> Result<()> {
            std::fs::write(p, body)
                .map_err(|e| CarinError::Io(format!("{}: {e}", p.display())))?;
            Ok(())
        };
        write(path, tel.events_jsonl())?;
        let mut prom = path.as_os_str().to_owned();
        prom.push(".prom");
        write(std::path::Path::new(&prom), tel.prometheus())?;
        Ok(Some(path.clone()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;
    use crate::runtime::{synthetic_manifest, StubEngine};

    #[test]
    fn builder_applies_policy_slo_and_capacity() {
        let reg = Registry::paper();
        let sol = config::pinned_uc3_solution(&reg);
        let manifest = synthetic_manifest(&reg);
        let policy = FaultPolicy { max_attempts: 7, ..FaultPolicy::default() };
        let coord = ServeOptions::new()
            .fault_policy(policy)
            .timeout_mult(4.0)
            .timeout_floor(Duration::from_millis(10))
            .latency_slo_ms(5.0)
            .event_capacity(32)
            .build_with_engine(StubEngine::new(), &reg, &sol, manifest)
            .unwrap();
        assert_eq!(coord.telemetry().recorder.capacity(), 32);
        // the watchdog deadline knobs reached the policy: SLO 5 ms × 4
        // is under the 10 ms floor, so the floor wins
        assert_eq!(
            crate::coordinator::serve::call_deadline(coord.fault_policy(), Some(5.0)),
            Some(Duration::from_millis(10))
        );
        assert_eq!(coord.fault_policy().max_attempts, 7);
    }

    #[test]
    fn both_coordinators_build_behind_the_trait() {
        let reg = Registry::paper();
        let sol = config::pinned_uc3_solution(&reg);
        let manifest = synthetic_manifest(&reg);
        let opts = ServeOptions::new();
        let mut single = opts
            .build_with_engine(StubEngine::new(), &reg, &sol, manifest.clone())
            .unwrap();
        let factory = |_: Engine| -> Result<StubEngine> { Ok(StubEngine::new()) };
        let mut pooled = opts.build_pooled(factory, &reg, &sol, manifest).unwrap();
        for coord in [&mut single as &mut dyn Coordinator, &mut pooled as &mut dyn Coordinator]
        {
            assert_eq!(coord.current_design(), 0);
            let (tx, rx) = mpsc::channel();
            drop(tx);
            let report = coord.serve(rx).unwrap();
            assert_eq!(report.total_requests, 0);
        }
    }

    #[test]
    fn dump_telemetry_without_destination_is_a_noop() {
        let tel = Telemetry::new(4);
        assert!(ServeOptions::new().dump_telemetry(&tel).unwrap().is_none());
    }
}
