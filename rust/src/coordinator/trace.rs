//! Adaptation trace driver: replays a workload + event schedule against
//! the device simulator with the Runtime Manager in the loop, recording
//! the per-inference timeline shown in Figures 7 and 8.

use std::collections::BTreeMap;

use crate::device::Simulator;
use crate::manager::{EventSchedule, Monitor, RuntimeManager};
use crate::moo::{Problem, Solution};
use crate::util::json::Json;

/// One recorded inference round (all tasks execute once, in parallel).
#[derive(Debug, Clone)]
pub struct TracePoint {
    pub t_s: f64,
    pub design: usize,
    /// Per-task latency of this round, ms.
    pub latency_ms: Vec<f64>,
    /// Per-task accuracy of the active design.
    pub accuracy: Vec<f64>,
    /// Throughput of task 0, inferences/s (Figure 7's y-axis).
    pub throughput: f64,
    /// Total design memory footprint, MB.
    pub mem_mb: f64,
    /// Events that fired just before this round.
    pub events: Vec<String>,
    /// Set when the RM switched design in this round.
    pub switched_to: Option<usize>,
}

impl TracePoint {
    /// The round as a JSON object (NaN accuracies serialize as `null`).
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("t_s".to_string(), Json::Num(self.t_s));
        m.insert("design".to_string(), Json::Num(self.design as f64));
        m.insert(
            "latency_ms".to_string(),
            Json::Arr(self.latency_ms.iter().map(|&v| Json::Num(v)).collect()),
        );
        m.insert(
            "accuracy".to_string(),
            Json::Arr(self.accuracy.iter().map(|&v| Json::Num(v)).collect()),
        );
        m.insert("throughput".to_string(), Json::Num(self.throughput));
        m.insert("mem_mb".to_string(), Json::Num(self.mem_mb));
        m.insert(
            "events".to_string(),
            Json::Arr(self.events.iter().map(|e| Json::Str(e.clone())).collect()),
        );
        m.insert(
            "switched_to".to_string(),
            match self.switched_to {
                Some(d) => Json::Num(d as f64),
                None => Json::Null,
            },
        );
        Json::Obj(m)
    }
}

/// A full adaptation run.
#[derive(Debug)]
pub struct TraceLog {
    pub points: Vec<TracePoint>,
    pub switches: usize,
    pub mean_decision_ns: f64,
}

impl TraceLog {
    /// The whole run as one JSON object (Figure-7/8 plotting input).
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("switches".to_string(), Json::Num(self.switches as f64));
        m.insert(
            "mean_decision_ns".to_string(),
            Json::Num(self.mean_decision_ns),
        );
        m.insert(
            "points".to_string(),
            Json::Arr(self.points.iter().map(|p| p.to_json()).collect()),
        );
        Json::Obj(m)
    }
}

/// Drive `solution` under `schedule` for `duration_s` of simulated time.
/// `period_s` is the inter-arrival period of the workload (e.g. 1/24 s
/// for UC1's camera stream).
pub fn run_trace(
    problem: &Problem,
    solution: Solution,
    mut schedule: EventSchedule,
    duration_s: f64,
    period_s: f64,
    seed: u64,
) -> TraceLog {
    let mut sim = Simulator::new(problem.device.clone(), seed);
    let mut monitor = Monitor::new(problem.device.engines.clone(), 2);
    let mut rm = RuntimeManager::new(solution);
    let mut points = Vec::new();

    let design_mf = |rm: &RuntimeManager, idx: usize| -> f64 {
        problem.metrics(&rm.solution.designs[idx].config).total_mf_bytes()
    };
    sim.load_app_bytes(design_mf(&rm, rm.current_design()));

    while sim.now_s < duration_s {
        let now = sim.now_s;
        let fired = schedule.apply_due(&mut sim, now);
        let state = monitor.sample(&sim);
        let switched_to = rm.observe(state, now);
        if let Some(idx) = switched_to {
            // load the new design's models, drop the old ones
            sim.load_app_bytes(design_mf(&rm, idx));
        }
        let design = rm.current_design();
        let cfg = rm.solution.designs[design].config.clone();

        // run one round: every task fires once, in parallel.
        let mut lat = Vec::with_capacity(cfg.assignments.len());
        let mut acc = Vec::with_capacity(cfg.assignments.len());
        for (t, a) in cfg.assignments.iter().enumerate() {
            let out = sim.run_inference(&problem.registry, a.variant, a.proc, cfg.co_located(t));
            lat.push(out.latency_ms);
            acc.push(a.variant.accuracy(&problem.registry).unwrap_or(f64::NAN));
            // parallel tasks: only the longest one advances the clock;
            // rewind the serial accumulation for all but the max.
        }
        let round_ms = lat.iter().copied().fold(0.0f64, f64::max);
        let serial_ms: f64 = lat.iter().sum();
        sim.now_s -= (serial_ms - round_ms) / 1000.0; // parallel correction
        let mem_mb = sim.ram.app_bytes / 1e6;
        points.push(TracePoint {
            t_s: now,
            design,
            throughput: 1000.0 / lat[0].max(1e-9),
            latency_ms: lat,
            accuracy: acc,
            mem_mb,
            events: fired.iter().map(|e| e.describe()).collect(),
            switched_to,
        });
        // wait out the arrival period
        if round_ms / 1000.0 < period_s {
            sim.idle(period_s - round_ms / 1000.0);
        }
    }

    TraceLog {
        switches: rm.switches.len(),
        mean_decision_ns: rm.mean_decision_ns(),
        points,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;
    use crate::device::profiles;
    use crate::manager::EventSchedule;
    use crate::moo::rass;
    use crate::zoo::Registry;

    #[test]
    fn figure7_trace_switches_and_recovers() {
        let p = config::use_case("uc1", &Registry::paper(), &profiles::galaxy_s20())
            .unwrap();
        let sol = rass::solve(&p);
        let sched = EventSchedule::figure7(p.device.ram_bytes());
        let log = run_trace(&p, sol, sched, 30.0, 1.0 / 24.0, 9);
        assert!(!log.points.is_empty());
        assert!(log.switches >= 2, "expected >=2 switches, got {}", log.switches);
        // all rounds ran on some design; design changes happened
        let designs: std::collections::HashSet<usize> =
            log.points.iter().map(|p| p.design).collect();
        assert!(designs.len() >= 2, "never switched design");
        // the run must return to the initial design once events clear
        assert_eq!(log.points.last().unwrap().design, log.points[0].design);
    }

    #[test]
    fn trace_log_round_trips_through_json() {
        let p = config::use_case("uc1", &Registry::paper(), &profiles::pixel7()).unwrap();
        let sol = rass::solve(&p);
        let log = run_trace(&p, sol, EventSchedule::default(), 1.0, 0.1, 5);
        let parsed = Json::parse(&log.to_json().dump()).expect("valid trace json");
        assert_eq!(
            parsed.get("switches").unwrap().as_usize().unwrap(),
            log.switches
        );
        let points = match parsed.get("points").unwrap() {
            Json::Arr(pts) => pts,
            other => panic!("expected array, got {other:?}"),
        };
        assert_eq!(points.len(), log.points.len());
        let first = &points[0];
        assert!(first.get("t_s").unwrap().as_f64().is_some());
        assert!(first.get("design").unwrap().as_usize().is_some());
        // no switch on round 0 -> null survives the round trip
        assert_eq!(first.get("switched_to"), Some(&Json::Null));
    }

    #[test]
    fn trace_time_advances_with_period() {
        let p = config::use_case("uc1", &Registry::paper(), &profiles::pixel7()).unwrap();
        let sol = rass::solve(&p);
        let log = run_trace(&p, sol, EventSchedule::default(), 2.0, 0.1, 3);
        // ~20 rounds in 2 s at 10 Hz
        assert!(log.points.len() >= 15 && log.points.len() <= 25,
                "{} rounds", log.points.len());
        assert_eq!(log.switches, 0);
    }
}
