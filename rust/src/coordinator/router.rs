//! Request router: maps a task's requests to the artifact of the design
//! currently selected by the Runtime Manager. Lookups are O(1) and
//! allocation-free on the hot path.

use crate::moo::Solution;
use crate::runtime::artifact::{self, ArtifactId, ArtifactMeta};
use crate::zoo::Registry;

/// Interned artifact names, built once from the manifest at coordinator
/// build time. [`ArtifactId`] is the manifest index; the table resolves
/// it back to the display stem at export/report time, so the hot path
/// only ever moves `Copy` ids (see ROADMAP "Memory path").
#[derive(Debug, Clone)]
pub struct RouteTable {
    names: Vec<String>,
}

impl RouteTable {
    /// Intern every manifest stem; ids are assigned in manifest order.
    pub fn from_manifest(manifest: &[ArtifactMeta]) -> RouteTable {
        RouteTable { names: manifest.iter().map(|m| m.stem.clone()).collect() }
    }

    /// Display stem of an interned artifact (export-time resolution).
    pub fn name(&self, id: ArtifactId) -> &str {
        &self.names[id.index()]
    }

    /// Reverse lookup, for string-keyed public APIs (`FaultInjector::
    /// set_for`) and tests. O(n); never on the request path.
    pub fn id_of(&self, stem: &str) -> Option<ArtifactId> {
        self.names.iter().position(|n| n == stem).map(|i| ArtifactId(i as u32))
    }

    /// Id of the `index`-th manifest entry.
    pub fn id(&self, index: usize) -> ArtifactId {
        debug_assert!(index < self.names.len());
        ArtifactId(index as u32)
    }

    pub fn len(&self) -> usize {
        self.names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }
}

/// Routes (task, current design) -> interned artifact id.
pub struct Router {
    /// `routes[design][task]` = index into the manifest.
    routes: Vec<Vec<usize>>,
    table: RouteTable,
    current: usize,
}

impl Router {
    /// Precompute the routing table for every design in the solution.
    /// Every design's (model, scheme) must resolve to an artifact via the
    /// registry's executable stand-in mapping.
    pub fn new(
        reg: &Registry,
        solution: &Solution,
        manifest: &[ArtifactMeta],
    ) -> anyhow::Result<Router> {
        let table = RouteTable::from_manifest(manifest);
        let mut routes = Vec::with_capacity(solution.designs.len());
        for d in &solution.designs {
            let mut per_task = Vec::with_capacity(d.config.assignments.len());
            for a in &d.config.assignments {
                let entry = &reg.models[a.variant.model];
                let scheme = a.variant.scheme.name();
                let meta = artifact::find(manifest, entry.artifact, scheme)
                    .ok_or_else(|| {
                        anyhow::anyhow!(
                            "no artifact {}_{} (stand-in for {})",
                            entry.artifact, scheme, entry.name
                        )
                    })?;
                per_task.push(
                    manifest.iter().position(|m| m.stem == meta.stem).unwrap(),
                );
            }
            routes.push(per_task);
        }
        Ok(Router { routes, table, current: 0 })
    }

    /// Point the router at a new design (called by the RM on switch).
    pub fn set_design(&mut self, design: usize) {
        assert!(design < self.routes.len());
        self.current = design;
    }

    pub fn design(&self) -> usize {
        self.current
    }

    /// Interned artifact id serving `task` right now. `Copy`, so the
    /// hot path never clones a stem `String`.
    pub fn route(&self, task: usize) -> ArtifactId {
        self.table.id(self.routes[self.current][task])
    }

    /// Display stem serving `task` right now (export-time resolution).
    pub fn route_stem(&self, task: usize) -> &str {
        self.table.name(self.route(task))
    }

    /// The interning table (id <-> stem) behind this router.
    pub fn table(&self) -> &RouteTable {
        &self.table
    }

    /// Manifest index serving `task` right now.
    pub fn route_index(&self, task: usize) -> usize {
        self.routes[self.current][task]
    }

    /// Manifest index serving `task` under an arbitrary design — lets the
    /// pooled dispatcher precompute every design's routing before workers
    /// spawn, independent of the currently selected design.
    pub fn route_index_for(&self, design: usize, task: usize) -> usize {
        self.routes[design][task]
    }

    /// Number of designs the routing table covers.
    pub fn n_designs(&self) -> usize {
        self.routes.len()
    }

    /// Every manifest index any design can route to (preload set) —
    /// CARIn's storage advantage (Table 10) is that *only* these are kept.
    pub fn preload_set(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.routes.iter().flatten().copied().collect();
        v.sort_unstable();
        v.dedup();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;
    use crate::device::profiles;
    use crate::moo::rass;
    use crate::runtime::load_manifest;
    use std::path::PathBuf;

    fn manifest_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn routes_every_design_of_every_use_case() {
        let dir = manifest_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let manifest = load_manifest(&dir).unwrap();
        let reg = Registry::paper();
        for dev in profiles::all() {
            for uc in config::USE_CASES {
                let p = config::use_case(uc, &reg, &dev).unwrap();
                let sol = rass::solve(&p);
                let router = Router::new(&reg, &sol, &manifest)
                    .unwrap_or_else(|e| panic!("{uc}/{}: {e}", dev.name));
                for (di, d) in sol.designs.iter().enumerate() {
                    let mut r = Router::new(&reg, &sol, &manifest).unwrap();
                    r.set_design(di);
                    for t in 0..d.config.assignments.len() {
                        assert!(!r.route_stem(t).is_empty());
                        assert_eq!(r.table().id_of(r.route_stem(t)), Some(r.route(t)));
                    }
                }
                assert!(!router.preload_set().is_empty());
            }
        }
    }
}
