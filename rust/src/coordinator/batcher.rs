//! Dynamic batcher: groups single-sample requests into fixed-size batch
//! tensors (UC4 runs its face models at batch 4) with a deadline so tail
//! requests are not starved. Formed batches keep every member's enqueue
//! timestamp and completion deadline, so the serving report can account
//! e2e latency and deadline hits per request rather than per batch.
//!
//! Memory path: payloads are [`TensorBuf`]s (`Arc`-backed), so enqueue
//! never copies sample data; capacity-1 batchers pass the request buffer
//! straight through, and multi-member batches concatenate into a buffer
//! leased from a shared [`BufferPool`] instead of a fresh `Vec`. Members
//! whose completion deadline has already expired are shed at formation
//! time ([`Formed::shed`]) rather than wasting a batch slot and engine
//! time on a guaranteed miss.

use std::time::{Duration, Instant};

use crate::error::CarinError;
use crate::util::{BufferPool, TensorBuf};

/// One enqueued request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// Flat input payload for one sample (shared, never deep-copied).
    pub payload: TensorBuf,
    pub enqueued: Instant,
    /// When the serve loop dequeued the request from the arrival channel
    /// (span boundary: queue wait ends, batch wait starts).
    pub admitted: Instant,
    /// Absolute completion deadline (None = no deadline).
    pub deadline: Option<Instant>,
}

/// A formed batch.
#[derive(Debug, Clone)]
pub struct Batch {
    pub ids: Vec<u64>,
    /// Concatenated payloads, padded with zero samples to `capacity`.
    /// Capacity-1 batchers alias the member's own buffer.
    pub payload: TensorBuf,
    /// Number of real (non-padding) samples.
    pub occupancy: usize,
    /// Per-member enqueue timestamps, aligned with `ids`.
    pub enqueued: Vec<Instant>,
    /// Per-member admission timestamps, aligned with `ids`.
    pub admitted: Vec<Instant>,
    /// Per-member deadlines, aligned with `ids`.
    pub deadlines: Vec<Option<Instant>>,
}

/// Outcome of a formation attempt: at most one batch, plus any members
/// shed because their deadline expired while they waited. The empty
/// `shed` vector does not allocate.
#[derive(Debug, Default)]
pub struct Formed {
    pub batch: Option<Batch>,
    /// Members dropped at formation time (already past their deadline);
    /// the caller counts them `shed` and emits the events.
    pub shed: Vec<Request>,
}

impl Formed {
    fn none() -> Formed {
        Formed { batch: None, shed: Vec::new() }
    }
}

/// Deadline-bounded fixed-capacity batcher.
#[derive(Debug)]
pub struct Batcher {
    capacity: usize,
    sample_len: usize,
    deadline: Duration,
    pending: Vec<Request>,
    pool: BufferPool,
}

impl Batcher {
    pub fn new(capacity: usize, sample_len: usize, deadline: Duration) -> Self {
        Batcher::with_pool(capacity, sample_len, deadline, BufferPool::default())
    }

    /// Like [`Batcher::new`] but forming batches out of a shared pool,
    /// so every batcher of a serving loop recycles the same slots.
    pub fn with_pool(
        capacity: usize,
        sample_len: usize,
        deadline: Duration,
        pool: BufferPool,
    ) -> Self {
        assert!(capacity > 0 && sample_len > 0);
        Batcher { capacity, sample_len, deadline, pending: Vec::new(), pool }
    }

    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    /// Enqueue; forms a batch when capacity is reached. A payload whose
    /// length does not match the batcher's sample length is rejected
    /// with [`CarinError::ShapeMismatch`] (the caller counts the request
    /// `failed`) instead of panicking the serve loop.
    pub fn push(&mut self, r: Request) -> Result<Formed, CarinError> {
        if r.payload.len() != self.sample_len {
            return Err(CarinError::ShapeMismatch {
                expected: self.sample_len,
                got: r.payload.len(),
            });
        }
        // formation-time "now": the admission timestamp of the request
        // that just arrived — fresh, and free of a clock read
        let now = r.admitted;
        self.pending.push(r);
        if self.pending.len() >= self.capacity {
            Ok(self.form(now, false))
        } else {
            Ok(Formed::none())
        }
    }

    /// Flush a partial batch whose oldest request exceeded the deadline.
    pub fn flush_due(&mut self, now: Instant) -> Formed {
        if self.pending.is_empty() {
            return Formed::none();
        }
        if now.duration_since(self.pending[0].enqueued) >= self.deadline {
            self.form(now, true)
        } else {
            Formed::none()
        }
    }

    /// Unconditional flush (shutdown path).
    pub fn flush(&mut self) -> Formed {
        if self.pending.is_empty() {
            Formed::none()
        } else {
            self.form(Instant::now(), true)
        }
    }

    fn form(&mut self, now: Instant, force: bool) -> Formed {
        // shed members already past their deadline: executing them can
        // only produce a counted miss, and they'd occupy a batch slot
        let mut shed = Vec::new();
        let mut i = 0;
        while i < self.pending.len() {
            if self.pending[i].deadline.is_some_and(|d| d <= now) {
                shed.push(self.pending.remove(i));
            } else {
                i += 1;
            }
        }
        // shedding may have left a push-triggered batch under capacity;
        // keep waiting unless this is a deadline/shutdown flush
        if self.pending.is_empty() || (!force && self.pending.len() < self.capacity) {
            return Formed { batch: None, shed };
        }
        let take = self.pending.len().min(self.capacity);
        let reqs: Vec<Request> = self.pending.drain(..take).collect();
        let payload = if self.capacity == 1 {
            // pass the request's own buffer through: no concatenation
            reqs[0].payload.clone()
        } else {
            self.pool.lease_with(self.capacity * self.sample_len, |buf| {
                for r in &reqs {
                    buf.extend_from_slice(&r.payload);
                }
            })
        };
        Formed {
            batch: Some(Batch {
                ids: reqs.iter().map(|r| r.id).collect(),
                payload,
                occupancy: reqs.len(),
                enqueued: reqs.iter().map(|r| r.enqueued).collect(),
                admitted: reqs.iter().map(|r| r.admitted).collect(),
                deadlines: reqs.iter().map(|r| r.deadline).collect(),
            }),
            shed,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, len: usize) -> Request {
        let now = Instant::now();
        Request {
            id,
            payload: vec![id as f32; len].into(),
            enqueued: now,
            admitted: now,
            deadline: None,
        }
    }

    /// push() for tests that only care about the formed batch.
    fn push_ok(b: &mut Batcher, r: Request) -> Option<Batch> {
        let formed = b.push(r).expect("shape ok");
        assert!(formed.shed.is_empty());
        formed.batch
    }

    #[test]
    fn batches_at_capacity() {
        let mut b = Batcher::new(4, 3, Duration::from_millis(5));
        assert!(push_ok(&mut b, req(0, 3)).is_none());
        assert!(push_ok(&mut b, req(1, 3)).is_none());
        assert!(push_ok(&mut b, req(2, 3)).is_none());
        let batch = push_ok(&mut b, req(3, 3)).expect("full batch");
        assert_eq!(batch.ids, vec![0, 1, 2, 3]);
        assert_eq!(batch.occupancy, 4);
        assert_eq!(batch.payload.len(), 12);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn never_exceeds_capacity_and_fifo() {
        let mut b = Batcher::new(2, 1, Duration::from_secs(1));
        push_ok(&mut b, req(5, 1));
        let batch = push_ok(&mut b, req(6, 1)).unwrap();
        assert_eq!(batch.ids, vec![5, 6]); // FIFO within the model
        assert!(batch.ids.len() <= 2);
    }

    #[test]
    fn deadline_flushes_partial_batch_padded() {
        let mut b = Batcher::new(4, 2, Duration::from_millis(0));
        push_ok(&mut b, req(9, 2));
        let batch = b.flush_due(Instant::now()).batch.expect("deadline flush");
        assert_eq!(batch.occupancy, 1);
        assert_eq!(batch.payload.len(), 8); // padded to capacity
        assert_eq!(&batch.payload[2..], &[0.0; 6]);
    }

    #[test]
    fn no_flush_before_deadline() {
        let mut b = Batcher::new(4, 1, Duration::from_secs(60));
        push_ok(&mut b, req(1, 1));
        assert!(b.flush_due(Instant::now()).batch.is_none());
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn unconditional_flush() {
        let mut b = Batcher::new(3, 1, Duration::from_secs(60));
        assert!(b.flush().batch.is_none());
        push_ok(&mut b, req(1, 1));
        assert_eq!(b.flush().batch.unwrap().occupancy, 1);
    }

    #[test]
    fn batch_carries_per_member_timestamps_and_deadlines() {
        let mut b = Batcher::new(2, 1, Duration::from_secs(60));
        let t0 = Instant::now();
        let t1 = t0 + Duration::from_millis(1);
        let dl = t0 + Duration::from_millis(50);
        push_ok(
            &mut b,
            Request {
                id: 1,
                payload: vec![1.0].into(),
                enqueued: t0,
                admitted: t1,
                deadline: Some(dl),
            },
        );
        let batch = push_ok(
            &mut b,
            Request {
                id: 2,
                payload: vec![2.0].into(),
                enqueued: t0,
                admitted: t1,
                deadline: None,
            },
        )
        .unwrap();
        assert_eq!(batch.enqueued.len(), 2);
        assert_eq!(batch.admitted, vec![t1, t1]);
        assert_eq!(batch.deadlines, vec![Some(dl), None]);
        // occupancy, ids and timestamps stay aligned
        assert_eq!(batch.ids.len(), batch.occupancy);
        assert_eq!(batch.enqueued.len(), batch.occupancy);
        assert_eq!(batch.admitted.len(), batch.occupancy);
    }

    #[test]
    fn shape_mismatch_is_a_typed_error_not_a_panic() {
        let mut b = Batcher::new(4, 3, Duration::from_millis(5));
        let err = b.push(req(7, 2)).unwrap_err();
        assert_eq!(err, CarinError::ShapeMismatch { expected: 3, got: 2 });
        assert_eq!(err.kind(), "shape");
        assert_eq!(b.pending(), 0, "bad request must not be enqueued");
        // the batcher still works afterwards
        for i in 0..4 {
            let _ = b.push(req(i, 3)).unwrap();
        }
    }

    #[test]
    fn expired_members_are_shed_at_formation() {
        let mut b = Batcher::new(4, 1, Duration::from_millis(10));
        let t0 = Instant::now();
        let mk = |id: u64, deadline: Option<Instant>| Request {
            id,
            payload: vec![id as f32].into(),
            enqueued: t0,
            admitted: t0,
            deadline,
        };
        // member 1's deadline expires before formation; 2 and 3 are live
        push_ok(&mut b, mk(1, Some(t0 + Duration::from_millis(1))));
        push_ok(&mut b, mk(2, Some(t0 + Duration::from_secs(30))));
        b.push(mk(3, None)).unwrap();
        let formed = b.flush_due(t0 + Duration::from_secs(1));
        assert_eq!(formed.shed.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1]);
        let batch = formed.batch.expect("live members still form a batch");
        assert_eq!(batch.ids, vec![2, 3]);
        assert_eq!(batch.occupancy, 2);
    }

    #[test]
    fn shedding_below_capacity_defers_push_triggered_batch() {
        let mut b = Batcher::new(2, 1, Duration::from_secs(60));
        let t0 = Instant::now();
        let expired = Request {
            id: 1,
            payload: vec![1.0].into(),
            enqueued: t0,
            admitted: t0,
            deadline: Some(t0),
        };
        b.push(expired).unwrap();
        // this push reaches capacity, but the expired member is shed and
        // the survivor waits for a peer instead of forming a half batch
        let late = Instant::now() + Duration::from_millis(10);
        let formed = b
            .push(Request {
                id: 2,
                payload: vec![2.0].into(),
                enqueued: late,
                admitted: late,
                deadline: None,
            })
            .unwrap();
        assert_eq!(formed.shed.len(), 1);
        assert!(formed.batch.is_none());
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn capacity_one_passes_request_buffer_through() {
        let mut b = Batcher::new(1, 4, Duration::from_millis(5));
        let r = req(3, 4);
        let ptr = r.payload.as_slice().as_ptr();
        let batch = push_ok(&mut b, r).expect("capacity-1 forms immediately");
        assert!(std::ptr::eq(ptr, batch.payload.as_slice().as_ptr()), "no copy");
        assert_eq!(batch.occupancy, 1);
    }

    #[test]
    fn multi_member_batches_reuse_pooled_buffers() {
        let pool = BufferPool::new(4);
        let mut b = Batcher::with_pool(2, 1, Duration::from_secs(60), pool.clone());
        let first = {
            push_ok(&mut b, req(1, 1));
            push_ok(&mut b, req(2, 1)).unwrap()
        };
        let ptr = first.payload.as_slice().as_ptr();
        drop(first);
        push_ok(&mut b, req(3, 1));
        let second = push_ok(&mut b, req(4, 1)).unwrap();
        assert!(std::ptr::eq(ptr, second.payload.as_slice().as_ptr()), "slot recycled");
        assert_eq!(second.payload.as_slice(), &[3.0, 4.0]);
        assert_eq!(pool.stats().hits, 1);
    }
}
