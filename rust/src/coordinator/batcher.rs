//! Dynamic batcher: groups single-sample requests into fixed-size batch
//! tensors (UC4 runs its face models at batch 4) with a deadline so tail
//! requests are not starved. Formed batches keep every member's enqueue
//! timestamp and completion deadline, so the serving report can account
//! e2e latency and deadline hits per request rather than per batch.

use std::time::{Duration, Instant};

/// One enqueued request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// Flat input payload for one sample.
    pub payload: Vec<f32>,
    pub enqueued: Instant,
    /// When the serve loop dequeued the request from the arrival channel
    /// (span boundary: queue wait ends, batch wait starts).
    pub admitted: Instant,
    /// Absolute completion deadline (None = no deadline).
    pub deadline: Option<Instant>,
}

/// A formed batch.
#[derive(Debug, Clone)]
pub struct Batch {
    pub ids: Vec<u64>,
    /// Concatenated payloads, padded with zero samples to `capacity`.
    pub payload: Vec<f32>,
    /// Number of real (non-padding) samples.
    pub occupancy: usize,
    /// Per-member enqueue timestamps, aligned with `ids`.
    pub enqueued: Vec<Instant>,
    /// Per-member admission timestamps, aligned with `ids`.
    pub admitted: Vec<Instant>,
    /// Per-member deadlines, aligned with `ids`.
    pub deadlines: Vec<Option<Instant>>,
}

/// Deadline-bounded fixed-capacity batcher.
#[derive(Debug)]
pub struct Batcher {
    capacity: usize,
    sample_len: usize,
    deadline: Duration,
    pending: Vec<Request>,
}

impl Batcher {
    pub fn new(capacity: usize, sample_len: usize, deadline: Duration) -> Self {
        assert!(capacity > 0 && sample_len > 0);
        Batcher { capacity, sample_len, deadline, pending: Vec::new() }
    }

    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    /// Enqueue; returns a full batch when capacity is reached.
    pub fn push(&mut self, r: Request) -> Option<Batch> {
        assert_eq!(r.payload.len(), self.sample_len, "sample length mismatch");
        self.pending.push(r);
        if self.pending.len() >= self.capacity {
            Some(self.form())
        } else {
            None
        }
    }

    /// Flush a partial batch whose oldest request exceeded the deadline.
    pub fn flush_due(&mut self, now: Instant) -> Option<Batch> {
        if self.pending.is_empty() {
            return None;
        }
        if now.duration_since(self.pending[0].enqueued) >= self.deadline {
            Some(self.form())
        } else {
            None
        }
    }

    /// Unconditional flush (shutdown path).
    pub fn flush(&mut self) -> Option<Batch> {
        if self.pending.is_empty() {
            None
        } else {
            Some(self.form())
        }
    }

    fn form(&mut self) -> Batch {
        let take = self.pending.len().min(self.capacity);
        let reqs: Vec<Request> = self.pending.drain(..take).collect();
        let mut payload = Vec::with_capacity(self.capacity * self.sample_len);
        for r in &reqs {
            payload.extend_from_slice(&r.payload);
        }
        payload.resize(self.capacity * self.sample_len, 0.0);
        Batch {
            ids: reqs.iter().map(|r| r.id).collect(),
            payload,
            occupancy: reqs.len(),
            enqueued: reqs.iter().map(|r| r.enqueued).collect(),
            admitted: reqs.iter().map(|r| r.admitted).collect(),
            deadlines: reqs.iter().map(|r| r.deadline).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, len: usize) -> Request {
        let now = Instant::now();
        Request {
            id,
            payload: vec![id as f32; len],
            enqueued: now,
            admitted: now,
            deadline: None,
        }
    }

    #[test]
    fn batches_at_capacity() {
        let mut b = Batcher::new(4, 3, Duration::from_millis(5));
        assert!(b.push(req(0, 3)).is_none());
        assert!(b.push(req(1, 3)).is_none());
        assert!(b.push(req(2, 3)).is_none());
        let batch = b.push(req(3, 3)).expect("full batch");
        assert_eq!(batch.ids, vec![0, 1, 2, 3]);
        assert_eq!(batch.occupancy, 4);
        assert_eq!(batch.payload.len(), 12);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn never_exceeds_capacity_and_fifo() {
        let mut b = Batcher::new(2, 1, Duration::from_secs(1));
        b.push(req(5, 1));
        let batch = b.push(req(6, 1)).unwrap();
        assert_eq!(batch.ids, vec![5, 6]); // FIFO within the model
        assert!(batch.ids.len() <= 2);
    }

    #[test]
    fn deadline_flushes_partial_batch_padded() {
        let mut b = Batcher::new(4, 2, Duration::from_millis(0));
        b.push(req(9, 2));
        let batch = b.flush_due(Instant::now()).expect("deadline flush");
        assert_eq!(batch.occupancy, 1);
        assert_eq!(batch.payload.len(), 8); // padded to capacity
        assert_eq!(&batch.payload[2..], &[0.0; 6]);
    }

    #[test]
    fn no_flush_before_deadline() {
        let mut b = Batcher::new(4, 1, Duration::from_secs(60));
        b.push(req(1, 1));
        assert!(b.flush_due(Instant::now()).is_none());
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn unconditional_flush() {
        let mut b = Batcher::new(3, 1, Duration::from_secs(60));
        assert!(b.flush().is_none());
        b.push(req(1, 1));
        assert_eq!(b.flush().unwrap().occupancy, 1);
    }

    #[test]
    fn batch_carries_per_member_timestamps_and_deadlines() {
        let mut b = Batcher::new(2, 1, Duration::from_secs(60));
        let t0 = Instant::now();
        let t1 = t0 + Duration::from_millis(1);
        let dl = t0 + Duration::from_millis(50);
        b.push(Request {
            id: 1,
            payload: vec![1.0],
            enqueued: t0,
            admitted: t1,
            deadline: Some(dl),
        });
        let batch = b
            .push(Request {
                id: 2,
                payload: vec![2.0],
                enqueued: t0,
                admitted: t1,
                deadline: None,
            })
            .unwrap();
        assert_eq!(batch.enqueued.len(), 2);
        assert_eq!(batch.admitted, vec![t1, t1]);
        assert_eq!(batch.deadlines, vec![Some(dl), None]);
        // occupancy, ids and timestamps stay aligned
        assert_eq!(batch.ids.len(), batch.occupancy);
        assert_eq!(batch.enqueued.len(), batch.occupancy);
        assert_eq!(batch.admitted.len(), batch.occupancy);
    }
}
