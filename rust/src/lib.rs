//! # carin — Constraint-Aware and Responsive Inference
//!
//! Rust reproduction of **CARIn** (Panopoulos, Venieris & Venieris, *ACM
//! TECS* 23(4), 2024, DOI 10.1145/3665868): a framework for deploying
//! single- and multi-DNN workloads on heterogeneous devices under
//! user-defined service-level objectives (SLOs).
//!
//! The crate is the Layer-3 coordinator of a three-layer stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): the tiled int8 /
//!   f32 matmul hot-spot every zoo model lowers onto.
//! * **L2** — JAX models (`python/compile/model.py`): the executable model
//!   zoo, AOT-lowered once to HLO text + `.npz` weights.
//! * **L3** — this crate: MOO problem construction ([`moo`]), the RASS
//!   solver ([`moo::rass`]), baseline solvers ([`moo::baselines`]), the
//!   heterogeneous-device simulator ([`device`]), profiling ([`profiler`]),
//!   the PJRT runtime ([`runtime`]), the Runtime Manager ([`manager`]),
//!   the serving coordinator ([`coordinator`]) and the telemetry
//!   subsystem ([`telemetry`]).
//!
//! Python never runs on the request path: `make artifacts` lowers the zoo
//! once, and the rust binary is self-contained afterwards.
//!
//! ## Quickstart
//!
//! ```no_run
//! use carin::prelude::*;
//!
//! // Formulate UC1 (real-time image classification) for the Galaxy S20.
//! let zoo = carin::zoo::Registry::paper();
//! let device = carin::device::profiles::by_name("s20").unwrap();
//! let problem = carin::config::use_case("uc1", &zoo, &device).unwrap();
//! let solution = carin::moo::rass::solve(&problem);
//! println!("initial design: {}", solution.designs[0].describe(&problem));
//! ```

pub mod bench;
pub mod config;
pub mod config_spec;
pub mod coordinator;
pub mod device;
pub mod error;
pub mod harness;
pub mod manager;
pub mod moo;
pub mod profiler;
pub mod runtime;
pub mod telemetry;
pub mod util;
pub mod workload;
pub mod zoo;

pub mod prelude {
    //! Convenience re-exports for examples and tests.
    pub use crate::config;
    pub use crate::coordinator::{Coordinator, ServeOptions};
    pub use crate::device::{profiles, Device, Engine};
    pub use crate::error::CarinError;
    pub use crate::manager::{Event, RuntimeManager};
    pub use crate::moo::{
        baselines, rass, Metric, Objective, Problem, Solution, Statistic,
    };
    pub use crate::zoo::{Registry, Scheme};
}
