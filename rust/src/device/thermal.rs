//! First-order thermal model of an SoC engine (paper §4.3.2: sustained
//! overload raises the die temperature until thermal throttling cuts the
//! clock). A simple RC model reproduces the trigger/recovery dynamics the
//! Runtime Manager must react to.

/// Thermal state of one engine.
#[derive(Debug, Clone)]
pub struct ThermalState {
    /// Current die temperature, °C.
    pub temp_c: f64,
    pub ambient_c: f64,
    pub throttle_c: f64,
    /// °C gained per joule dissipated.
    pub heat_per_joule: f64,
    /// Fraction of the excess-over-ambient shed per second.
    pub cooling_rate: f64,
}

impl ThermalState {
    pub fn new(ambient_c: f64, throttle_c: f64) -> Self {
        ThermalState {
            temp_c: ambient_c,
            ambient_c,
            throttle_c,
            heat_per_joule: 0.9,
            cooling_rate: 0.12,
        }
    }

    /// Advance the model: `energy_j` dissipated over `dt_s` seconds.
    pub fn step(&mut self, energy_j: f64, dt_s: f64) {
        self.temp_c += energy_j * self.heat_per_joule;
        let excess = self.temp_c - self.ambient_c;
        self.temp_c -= excess * (1.0 - (-self.cooling_rate * dt_s).exp());
        self.temp_c = self.temp_c.max(self.ambient_c);
    }

    /// Clock multiplier in (0, 1]: 1.0 below the throttle threshold,
    /// degrading linearly to a 0.45 floor 12 °C above it.
    pub fn clock_factor(&self) -> f64 {
        if self.temp_c <= self.throttle_c {
            1.0
        } else {
            let over = ((self.temp_c - self.throttle_c) / 12.0).min(1.0);
            (1.0 - 0.55 * over).max(0.45)
        }
    }

    pub fn throttled(&self) -> bool {
        self.temp_c > self.throttle_c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn heats_under_load_and_cools_idle() {
        let mut t = ThermalState::new(28.0, 44.0);
        for _ in 0..100 {
            t.step(0.5, 0.05); // 10 W sustained
        }
        assert!(t.temp_c > 35.0, "temp {}", t.temp_c);
        let hot = t.temp_c;
        for _ in 0..200 {
            t.step(0.0, 0.5); // idle
        }
        assert!(t.temp_c < hot);
        assert!(t.temp_c >= t.ambient_c);
    }

    #[test]
    fn clock_floor_never_below_045() {
        let mut t = ThermalState::new(28.0, 44.0);
        t.temp_c = 200.0;
        assert!(t.clock_factor() >= 0.45);
    }

    #[test]
    fn no_throttle_below_threshold() {
        let t = ThermalState::new(28.0, 44.0);
        assert_eq!(t.clock_factor(), 1.0);
        assert!(!t.throttled());
    }

    #[test]
    fn throttle_engages_above_threshold() {
        let mut t = ThermalState::new(28.0, 44.0);
        t.temp_c = 50.0;
        assert!(t.throttled());
        assert!(t.clock_factor() < 1.0);
    }
}
