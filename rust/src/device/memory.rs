//! Memory-footprint model and RAM-pressure accounting (paper §4.3.2:
//! "Variability in RAM Utilisation").
//!
//! `MF` (paper §4.1.1) is the RAM required to load and execute a DNN:
//! runtime base (interpreter + delegate buffers) + weights + peak
//! activations. Background apps claim and release RAM over time, which is
//! what trips the `c_m` monitor at runtime.

use crate::zoo::registry::{Family, ModelEntry};
use crate::zoo::{Scheme, Variant};

use super::{Engine, Proc};
use crate::zoo::Registry;

/// Runtime base footprint of a delegate, bytes (interpreter, command
/// queues, staging buffers). GPU delegates are the heaviest (shader
/// programs + dual copies of I/O buffers).
pub fn runtime_base_bytes(proc: Proc) -> f64 {
    match proc.engine() {
        Engine::Cpu => {
            if let Proc::Cpu { xnnpack: true, .. } = proc {
                12e6
            } else {
                8e6
            }
        }
        Engine::Gpu => 38e6,
        Engine::Npu => 24e6,
        Engine::Dsp => 18e6,
    }
}

/// Peak activation bytes: a sub-linear function of workload — activation
/// tensors grow with feature-map size, not with parameter count. fp16
/// execution halves them; integer execution quarters them.
pub fn activation_bytes(entry: &ModelEntry, scheme: Scheme) -> f64 {
    let flops = entry.gflops * 1e9;
    let base = match entry.family {
        Family::Cnn => 9.0 * flops.powf(0.62),
        Family::Transformer => 5.0 * flops.powf(0.62),
        Family::Audio => 6.0 * flops.powf(0.62),
    } * entry.batch as f64;
    let f = match scheme {
        Scheme::Fp32 | Scheme::Dr8 => 1.0,
        Scheme::Fp16 => 0.55,
        Scheme::Fx8 => 0.45,
        Scheme::Ffx8 => 0.30,
    };
    base * f
}

/// Total memory footprint of running `variant` on `proc`, bytes.
pub fn footprint_bytes(reg: &Registry, variant: Variant, proc: Proc) -> f64 {
    let entry = &reg.models[variant.model];
    let weights = variant.size_bytes(reg);
    // fp16 weights are dequantised to fp32 on CPU fallback (Table 1),
    // doubling their in-RAM copy.
    let weights_in_ram = if variant.scheme == Scheme::Fp16
        && proc.engine() == Engine::Cpu
    {
        weights * 2.0
    } else {
        weights
    };
    runtime_base_bytes(proc) + weights_in_ram + activation_bytes(entry, variant.scheme)
}

/// RAM-pressure tracker: total device RAM vs what the OS + background
/// apps + our designs currently hold.
#[derive(Debug, Clone)]
pub struct RamState {
    pub total_bytes: f64,
    /// OS + resident services (fixed floor).
    pub os_bytes: f64,
    /// Fluctuating background-app usage.
    pub background_bytes: f64,
    /// Bytes held by the inference application.
    pub app_bytes: f64,
}

impl RamState {
    pub fn new(total_bytes: f64) -> Self {
        RamState {
            total_bytes,
            os_bytes: total_bytes * 0.35,
            background_bytes: total_bytes * 0.15,
            app_bytes: 0.0,
        }
    }

    pub fn used(&self) -> f64 {
        self.os_bytes + self.background_bytes + self.app_bytes
    }

    pub fn available(&self) -> f64 {
        (self.total_bytes - self.used()).max(0.0)
    }

    /// Utilisation in [0, 1].
    pub fn utilisation(&self) -> f64 {
        (self.used() / self.total_bytes).min(1.0)
    }

    /// The `c_m` monitor signal (paper §4.3.4): memory pressure when
    /// utilisation crosses 90%.
    pub fn pressured(&self) -> bool {
        self.utilisation() > 0.90
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;

    #[test]
    fn quantisation_shrinks_footprint() {
        let reg = Registry::paper();
        let i = reg.find("MobileBERT-L24-H512").unwrap();
        let proc = Proc::Cpu { threads: 4, xnnpack: true };
        let f32 = footprint_bytes(&reg, Variant { model: i, scheme: Scheme::Fp32 }, proc);
        let dr8 = footprint_bytes(&reg, Variant { model: i, scheme: Scheme::Dr8 }, proc);
        assert!(dr8 < f32 / 2.0);
    }

    #[test]
    fn uc2_constraint_bites_mobilebert_fp32() {
        // The UC2 narrow SLO bounds MF at 90 MB; MobileBERT fp32 weights
        // alone are ~101 MB, so the constraint must exclude it.
        let reg = Registry::paper();
        let i = reg.find("MobileBERT-L24-H512").unwrap();
        let proc = Proc::Cpu { threads: 4, xnnpack: true };
        let mf = footprint_bytes(&reg, Variant { model: i, scheme: Scheme::Fp32 }, proc);
        assert!(mf > 90e6, "mf = {} MB", mf / 1e6);
        let mf8 = footprint_bytes(&reg, Variant { model: i, scheme: Scheme::Fx8 }, proc);
        assert!(mf8 < 90e6, "mf8 = {} MB", mf8 / 1e6);
    }

    #[test]
    fn gpu_base_heavier_than_cpu() {
        assert!(runtime_base_bytes(Proc::Gpu)
            > runtime_base_bytes(Proc::Cpu { threads: 1, xnnpack: false }));
    }

    #[test]
    fn ram_state_accounting() {
        let d = profiles::galaxy_s20();
        let mut ram = RamState::new(d.ram_bytes());
        assert!(!ram.pressured());
        let avail0 = ram.available();
        ram.app_bytes = 100e6;
        assert!((avail0 - ram.available() - 100e6).abs() < 1.0);
        ram.background_bytes = d.ram_bytes() * 0.58;
        assert!(ram.pressured());
    }

    #[test]
    fn batch4_inflates_activations() {
        let reg = Registry::paper();
        let g = reg.find("GenderNet-MNV2").unwrap();
        let img = reg.find("MobileNet V2 1.0").unwrap();
        let a_face = activation_bytes(&reg.models[g], Scheme::Fp32);
        // per-batch-item activations smaller than the 224px model's
        let a_img = activation_bytes(&reg.models[img], Scheme::Fp32);
        assert!(a_face / 4.0 < a_img);
    }
}
