//! Per-engine performance model: effective throughput, dispatch overhead,
//! latency jitter and power draw. Calibrated so that orderings and ratios
//! match the paper's qualitative findings (NPUs dominate integer CNNs,
//! GPUs dominate fp16, CPUs scale sub-linearly with threads, transformers
//! vectorise poorly on fixed-function engines).

use crate::zoo::registry::Family;
use crate::zoo::Scheme;

use super::Proc;

/// Static performance description of one engine on one device.
#[derive(Debug, Clone)]
pub struct EnginePerf {
    /// Effective single-thread (CPU) / base (others) throughput in GFLOP/s
    /// for float32 graphs.
    pub f32_gflops: f64,
    /// ... for fp16 graphs (falls back to f32 speed where unsupported).
    pub f16_gflops: f64,
    /// ... for integer-dominant graphs (DR8/FX8/FFX8).
    pub int8_gflops: f64,
    /// Fixed dispatch + interpreter overhead per inference, ms.
    pub overhead_ms: f64,
    /// Log-normal sigma of run-to-run latency jitter.
    pub noise_sigma: f64,
    /// Active power draw in watts at full utilisation.
    pub power_w: f64,
    /// Multiplier applied to transformer-family models (self-attention
    /// maps poorly onto fixed-function conv engines).
    pub transformer_factor: f64,
}

impl EnginePerf {
    /// Effective throughput in GFLOP/s for a (proc, scheme, family) triple.
    pub fn throughput(&self, proc: Proc, scheme: Scheme, family: Family) -> f64 {
        let base = match scheme {
            Scheme::Fp32 => self.f32_gflops,
            Scheme::Fp16 => self.f16_gflops,
            // DR8 pays the per-tensor dynamic-quantise pass.
            Scheme::Dr8 => self.int8_gflops * 0.85,
            Scheme::Fx8 => self.int8_gflops,
            Scheme::Ffx8 => self.int8_gflops * 1.05, // no float I/O conversions
        };
        let family_f = match family {
            Family::Transformer => self.transformer_factor,
            Family::Audio | Family::Cnn => 1.0,
        };
        base * family_f * cpu_scaling(proc, scheme)
    }

    /// Mean latency in ms for `flops` of work.
    pub fn latency_ms(&self, flops: f64, proc: Proc, scheme: Scheme, family: Family) -> f64 {
        self.overhead_ms + flops / (self.throughput(proc, scheme, family) * 1e6)
    }
}

/// CPU multi-threading + XNNPACK scaling. Threads scale sub-linearly
/// (memory-bound tails, little cores joining at 4+); XNNPACK's optimised
/// kernels help float graphs ~1.5x and symmetric-int8 graphs ~2x
/// (paper §6.4).
fn cpu_scaling(proc: Proc, scheme: Scheme) -> f64 {
    match proc {
        Proc::Cpu { threads, xnnpack } => {
            let t = (threads as f64).powf(0.72);
            let x = if xnnpack {
                if scheme.is_integer() { 2.0 } else { 1.5 }
            } else {
                1.0
            };
            t * x
        }
        _ => 1.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn perf() -> EnginePerf {
        EnginePerf {
            f32_gflops: 10.0,
            f16_gflops: 12.0,
            int8_gflops: 20.0,
            overhead_ms: 0.5,
            noise_sigma: 0.05,
            power_w: 2.0,
            transformer_factor: 0.6,
        }
    }

    #[test]
    fn thread_scaling_monotone_sublinear() {
        let p = perf();
        let l1 = |t| {
            p.latency_ms(1e9, Proc::Cpu { threads: t, xnnpack: false },
                         Scheme::Fp32, Family::Cnn)
        };
        assert!(l1(1) > l1(2) && l1(2) > l1(4) && l1(4) > l1(8));
        // sublinear: 8 threads less than 8x faster
        assert!(l1(1) / l1(8) < 8.0);
    }

    #[test]
    fn xnnpack_speeds_up_int8_more() {
        let p = perf();
        let lat = |scheme, xnn| {
            p.latency_ms(1e9, Proc::Cpu { threads: 4, xnnpack: xnn }, scheme,
                         Family::Cnn)
        };
        let f32_gain = lat(Scheme::Fp32, false) / lat(Scheme::Fp32, true);
        let int8_gain = lat(Scheme::Ffx8, false) / lat(Scheme::Ffx8, true);
        assert!(int8_gain > f32_gain);
    }

    #[test]
    fn transformer_penalty_applies() {
        let p = perf();
        let cnn = p.latency_ms(1e9, Proc::Npu, Scheme::Fx8, Family::Cnn);
        let tfm = p.latency_ms(1e9, Proc::Npu, Scheme::Fx8, Family::Transformer);
        assert!(tfm > cnn);
    }

    #[test]
    fn overhead_dominates_tiny_models() {
        let p = perf();
        let l = p.latency_ms(1e3, Proc::Gpu, Scheme::Fp16, Family::Cnn);
        assert!((l - p.overhead_ms).abs() < 1e-3);
    }
}
