//! Heterogeneous-device substrate.
//!
//! The paper evaluates on three Android phones (Table 6) through TFLite
//! delegates; none of that hardware exists in this environment, so — per
//! the substitution rule in DESIGN.md §6 — this module implements a
//! behavioural simulator that preserves what the MOO/RASS layers consume:
//! per-(engine, scheme, family) latency and energy distributions, memory
//! footprints, scheme-compatibility masks, thread/XNNPACK scaling,
//! thermal-throttling dynamics and RAM pressure.

pub mod memory;
pub mod perf;
pub mod profiles;
pub mod simulator;
pub mod thermal;

pub use perf::EnginePerf;
pub use profiles::Device;
pub use simulator::{Governor, Simulator};

use crate::zoo::Scheme;

/// Compute engines (paper §6.3: `ce ∈ CE`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Engine {
    Cpu,
    Gpu,
    Npu,
    Dsp,
}

impl Engine {
    pub const ALL: [Engine; 4] = [Engine::Cpu, Engine::Gpu, Engine::Npu, Engine::Dsp];

    pub fn name(self) -> &'static str {
        match self {
            Engine::Cpu => "CPU",
            Engine::Gpu => "GPU",
            Engine::Npu => "NPU",
            Engine::Dsp => "DSP",
        }
    }

    pub fn index(self) -> usize {
        match self {
            Engine::Cpu => 0,
            Engine::Gpu => 1,
            Engine::Npu => 2,
            Engine::Dsp => 3,
        }
    }
}

/// A processor configuration `hw = (ce, op(ce))` (paper §3.2).
///
/// `op(CPU) = {threads ∈ {1,2,4,8}, xnnpack}`; GPU/NPU run fp16 where
/// feasible; the DSP exposes no options (paper §6.4).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Proc {
    Cpu { threads: u8, xnnpack: bool },
    Gpu,
    Npu,
    Dsp,
}

impl Proc {
    pub fn engine(self) -> Engine {
        match self {
            Proc::Cpu { .. } => Engine::Cpu,
            Proc::Gpu => Engine::Gpu,
            Proc::Npu => Engine::Npu,
            Proc::Dsp => Engine::Dsp,
        }
    }

    /// All CPU option combinations (8 of them: 4 thread counts x XNNPACK).
    pub fn cpu_options() -> Vec<Proc> {
        let mut v = Vec::with_capacity(8);
        for &threads in &[1u8, 2, 4, 8] {
            for &xnnpack in &[false, true] {
                v.push(Proc::Cpu { threads, xnnpack });
            }
        }
        v
    }

    pub fn describe(self) -> String {
        match self {
            Proc::Cpu { threads, xnnpack } => {
                format!("CPU[{}t{}]", threads, if xnnpack { ",xnn" } else { "" })
            }
            Proc::Gpu => "GPU".into(),
            Proc::Npu => "NPU".into(),
            Proc::Dsp => "DSP".into(),
        }
    }
}

/// Scheme compatibility of an engine on a given device family
/// (paper §6.1/§6.3: DSPs and the A71 HTA are integer-only; GPUs prefer
/// fp16 and run FX8 through the float-fallback path; DR8's dynamic
/// quantisation is CPU-only in TFLite).
pub fn compatible(device: &Device, proc: Proc, scheme: Scheme) -> bool {
    match proc.engine() {
        Engine::Cpu => true,
        Engine::Gpu => matches!(scheme, Scheme::Fp32 | Scheme::Fp16 | Scheme::Fx8),
        Engine::Npu => {
            if device.npu_integer_only {
                matches!(scheme, Scheme::Fx8 | Scheme::Ffx8)
            } else {
                matches!(scheme, Scheme::Fp16 | Scheme::Fx8 | Scheme::Ffx8)
            }
        }
        Engine::Dsp => matches!(scheme, Scheme::Ffx8),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_option_space_is_8() {
        assert_eq!(Proc::cpu_options().len(), 8);
    }

    #[test]
    fn dsp_is_integer_only() {
        let a71 = profiles::by_name("a71").unwrap();
        assert!(compatible(&a71, Proc::Dsp, Scheme::Ffx8));
        assert!(!compatible(&a71, Proc::Dsp, Scheme::Fp32));
        assert!(!compatible(&a71, Proc::Dsp, Scheme::Fp16));
    }

    #[test]
    fn s20_npu_runs_fp16() {
        let s20 = profiles::by_name("s20").unwrap();
        assert!(compatible(&s20, Proc::Npu, Scheme::Fp16));
        let a71 = profiles::by_name("a71").unwrap();
        assert!(!compatible(&a71, Proc::Npu, Scheme::Fp16)); // HTA int-only
    }

    #[test]
    fn gpu_rejects_dr8_and_ffx8() {
        let p7 = profiles::by_name("p7").unwrap();
        assert!(!compatible(&p7, Proc::Gpu, Scheme::Dr8));
        assert!(!compatible(&p7, Proc::Gpu, Scheme::Ffx8));
        assert!(compatible(&p7, Proc::Gpu, Scheme::Fx8)); // float fallback
    }
}
