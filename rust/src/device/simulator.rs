//! Dynamic device simulator: ties the static performance model
//! ([`super::perf`]) to run-time state — thermal throttling, external
//! (background) load, co-located multi-DNN contention and RAM pressure.
//! This is what the profiler samples offline and what the Runtime
//! Manager monitors online.

use crate::util::Rng;
use crate::zoo::{Registry, Variant};

use super::memory::{footprint_bytes, RamState};
use super::thermal::ThermalState;
use super::{Device, Engine, Proc};

/// One simulated inference outcome.
#[derive(Debug, Clone, Copy)]
pub struct InferenceOutcome {
    pub latency_ms: f64,
    pub energy_mj: f64,
}

/// DVFS governor (paper §3.2: the tunable-system-parameter tuple can be
/// extended with the DVFS governor selection, as in OODIn).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Governor {
    /// Pins the highest OPP: fastest, hottest.
    Performance,
    /// Load-tracking default.
    #[default]
    Schedutil,
    /// Caps the frequency: slow but cool and frugal.
    Powersave,
}

impl Governor {
    /// Clock multiplier applied on top of thermal throttling.
    pub fn clock_factor(self) -> f64 {
        match self {
            Governor::Performance => 1.0,
            Governor::Schedutil => 0.96,
            Governor::Powersave => 0.62,
        }
    }

    /// Power multiplier (V-f scaling: power falls faster than clock).
    pub fn power_factor(self) -> f64 {
        match self {
            Governor::Performance => 1.15,
            Governor::Schedutil => 1.0,
            Governor::Powersave => 0.55,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Governor::Performance => "performance",
            Governor::Schedutil => "schedutil",
            Governor::Powersave => "powersave",
        }
    }
}

/// Dynamic device state.
#[derive(Debug, Clone)]
pub struct Simulator {
    pub device: Device,
    thermal: Vec<ThermalState>,
    pub ram: RamState,
    /// External (background) utilisation per engine, 0..1 — injected by
    /// runtime events (paper §4.3.2 "processor overload").
    external_load: [f64; 4],
    /// Simulated wall-clock, seconds.
    pub now_s: f64,
    /// Active DVFS governor (device-wide, as Android exposes it).
    pub governor: Governor,
    rng: Rng,
}

impl Simulator {
    pub fn new(device: Device, seed: u64) -> Self {
        let thermal = (0..4)
            .map(|_| ThermalState::new(device.ambient_c, device.throttle_c))
            .collect();
        let ram = RamState::new(device.ram_bytes());
        Simulator {
            device,
            thermal,
            ram,
            external_load: [0.0; 4],
            now_s: 0.0,
            governor: Governor::default(),
            rng: Rng::new(seed),
        }
    }

    pub fn set_governor(&mut self, g: Governor) {
        self.governor = g;
    }

    // ---- event-injection surface (used by manager::events) --------------

    pub fn set_external_load(&mut self, engine: Engine, load: f64) {
        self.external_load[engine.index()] = load.clamp(0.0, 1.0);
    }

    pub fn external_load(&self, engine: Engine) -> f64 {
        self.external_load[engine.index()]
    }

    pub fn set_background_ram(&mut self, bytes: f64) {
        self.ram.background_bytes = bytes.max(0.0);
    }

    pub fn thermal(&self, engine: Engine) -> &ThermalState {
        &self.thermal[engine.index()]
    }

    /// Force a die temperature (tests / event injection).
    pub fn set_temperature(&mut self, engine: Engine, temp_c: f64) {
        self.thermal[engine.index()].temp_c = temp_c;
    }

    // ---- monitor signals (consumed by the Runtime Manager) ---------------

    /// The paper's `c_ce` boolean: engine overloaded or overheated.
    pub fn engine_troubled(&self, engine: Engine) -> bool {
        self.thermal[engine.index()].throttled()
            || self.external_load[engine.index()] > 0.70
    }

    /// The paper's `c_m` boolean.
    pub fn memory_pressured(&self) -> bool {
        self.ram.pressured()
    }

    // ---- execution --------------------------------------------------------

    /// Sample the latency of one inference of `variant` on `proc`, given
    /// `co_located` other DNNs currently mapped to the same engine.
    /// Does not mutate thermal state (pure sampling; used by the profiler).
    pub fn sample_latency_ms(
        &mut self,
        reg: &Registry,
        variant: Variant,
        proc: Proc,
        co_located: usize,
    ) -> f64 {
        let entry = &reg.models[variant.model];
        let engine = proc.engine();
        let perf = self.device.perf(engine);
        let mean = perf.latency_ms(
            variant.flops(reg) * entry.batch as f64,
            proc,
            variant.scheme,
            entry.family,
        );
        let clock = self.thermal[engine.index()].clock_factor()
            * self.governor.clock_factor();
        // External load steals cycles; co-located DNNs time-slice the
        // engine almost linearly (paper §2.1.3).
        let ext = 1.0 + 1.6 * self.external_load[engine.index()];
        let co = ((co_located + 1) as f64).powf(0.95);
        // RAM pressure causes paging stalls once past the monitor threshold.
        let mem = if self.ram.pressured() { 1.25 } else { 1.0 };
        let jitter = self.rng.jitter(perf.noise_sigma);
        mean / clock * ext * co * mem * jitter
    }

    /// Execute one inference: samples latency, accounts energy, heats the
    /// engine and advances simulated time.
    pub fn run_inference(
        &mut self,
        reg: &Registry,
        variant: Variant,
        proc: Proc,
        co_located: usize,
    ) -> InferenceOutcome {
        let latency_ms = self.sample_latency_ms(reg, variant, proc, co_located);
        let engine = proc.engine();
        let power = self.engine_power_w(proc);
        let energy_mj = power * latency_ms; // W * ms = mJ
        self.thermal[engine.index()].step(energy_mj / 1000.0, latency_ms / 1000.0);
        self.now_s += latency_ms / 1000.0;
        InferenceOutcome { latency_ms, energy_mj }
    }

    /// Let `dt_s` of idle time pass (engines cool; no work done).
    pub fn idle(&mut self, dt_s: f64) {
        for t in &mut self.thermal {
            t.step(0.0, dt_s);
        }
        self.now_s += dt_s;
    }

    /// Account the memory of a design being loaded/unloaded.
    pub fn load_app_bytes(&mut self, bytes: f64) {
        self.ram.app_bytes = bytes.max(0.0);
    }

    /// Memory footprint of running `variant` on `proc` (deterministic).
    pub fn footprint_bytes(&self, reg: &Registry, variant: Variant, proc: Proc) -> f64 {
        footprint_bytes(reg, variant, proc)
    }

    fn engine_power_w(&self, proc: Proc) -> f64 {
        let perf = self.device.perf(proc.engine());
        let base = match proc {
            Proc::Cpu { threads, .. } => {
                // per-cluster power: big cores first, diminishing additions.
                perf.power_w * (threads as f64).powf(0.8)
            }
            _ => perf.power_w,
        };
        base * self.governor.power_factor()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::zoo::Scheme;

    fn sim() -> (Registry, Simulator) {
        (Registry::paper(), Simulator::new(profiles::galaxy_s20(), 42))
    }

    fn mnv2(reg: &Registry) -> Variant {
        Variant { model: reg.find("MobileNet V2 1.0").unwrap(), scheme: Scheme::Fp32 }
    }

    #[test]
    fn latency_positive_and_noisy() {
        let (reg, mut sim) = sim();
        let v = mnv2(&reg);
        let p = Proc::Cpu { threads: 4, xnnpack: true };
        let samples: Vec<f64> =
            (0..50).map(|_| sim.sample_latency_ms(&reg, v, p, 0)).collect();
        assert!(samples.iter().all(|&x| x > 0.0));
        let s = crate::util::Summary::of(&samples);
        assert!(s.cv() > 0.01 && s.cv() < 0.5, "cv = {}", s.cv());
    }

    #[test]
    fn external_load_slows_inference() {
        let (reg, mut sim) = sim();
        let v = mnv2(&reg);
        let p = Proc::Cpu { threads: 4, xnnpack: true };
        let base: f64 =
            (0..40).map(|_| sim.sample_latency_ms(&reg, v, p, 0)).sum::<f64>() / 40.0;
        sim.set_external_load(Engine::Cpu, 0.9);
        let loaded: f64 =
            (0..40).map(|_| sim.sample_latency_ms(&reg, v, p, 0)).sum::<f64>() / 40.0;
        assert!(loaded > base * 1.5, "{loaded} vs {base}");
    }

    #[test]
    fn co_location_slows_inference_monotonically() {
        let (reg, mut sim) = sim();
        let v = mnv2(&reg);
        let p = Proc::Gpu;
        let avg = |sim: &mut Simulator, k| {
            (0..40).map(|_| sim.sample_latency_ms(&reg, v, p, k)).sum::<f64>() / 40.0
        };
        let l0 = avg(&mut sim, 0);
        let l1 = avg(&mut sim, 1);
        let l2 = avg(&mut sim, 2);
        assert!(l0 < l1 && l1 < l2);
    }

    #[test]
    fn sustained_load_triggers_thermal_trouble() {
        let (reg, mut sim) = sim();
        let v = Variant {
            model: reg.find("EfficientNet Lite4").unwrap(),
            scheme: Scheme::Fp16,
        };
        assert!(!sim.engine_troubled(Engine::Gpu));
        for _ in 0..3000 {
            sim.run_inference(&reg, v, Proc::Gpu, 0);
        }
        assert!(sim.engine_troubled(Engine::Gpu), "temp {}", sim.thermal(Engine::Gpu).temp_c);
        // and inferences got slower than cold-start ones
    }

    #[test]
    fn idle_cools_down() {
        let (reg, mut sim) = sim();
        let v = mnv2(&reg);
        for _ in 0..2000 {
            sim.run_inference(&reg, v, Proc::Gpu, 0);
        }
        let hot = sim.thermal(Engine::Gpu).temp_c;
        sim.idle(120.0);
        assert!(sim.thermal(Engine::Gpu).temp_c < hot);
    }

    #[test]
    fn energy_scales_with_latency() {
        let (reg, mut sim) = sim();
        let small = Variant { model: reg.find("MobileNet V2 1.0").unwrap(), scheme: Scheme::Fp32 };
        let big = Variant { model: reg.find("EfficientNet Lite4").unwrap(), scheme: Scheme::Fp32 };
        let p = Proc::Cpu { threads: 4, xnnpack: true };
        let e_small = sim.run_inference(&reg, small, p, 0).energy_mj;
        let e_big = sim.run_inference(&reg, big, p, 0).energy_mj;
        assert!(e_big > e_small);
    }

    #[test]
    fn memory_pressure_signal() {
        let (_, mut sim) = sim();
        assert!(!sim.memory_pressured());
        sim.set_background_ram(sim.device.ram_bytes() * 0.62);
        assert!(sim.memory_pressured());
    }

    #[test]
    fn deterministic_given_seed() {
        let (reg, mut a) = sim();
        let mut b = Simulator::new(profiles::galaxy_s20(), 42);
        let v = mnv2(&reg);
        let p = Proc::Gpu;
        for _ in 0..10 {
            assert_eq!(
                a.sample_latency_ms(&reg, v, p, 0),
                b.sample_latency_ms(&reg, v, p, 0)
            );
        }
    }
}

#[cfg(test)]
mod governor_tests {
    use super::*;
    use crate::device::profiles;
    use crate::zoo::Scheme;

    #[test]
    fn powersave_slower_but_frugal() {
        let reg = Registry::paper();
        let v = Variant { model: reg.find("MobileNet V2 1.0").unwrap(), scheme: Scheme::Fp32 };
        let p = Proc::Cpu { threads: 4, xnnpack: true };
        let run = |g: Governor| {
            let mut sim = Simulator::new(profiles::galaxy_s20(), 77);
            sim.set_governor(g);
            let outs: Vec<_> = (0..40).map(|_| sim.run_inference(&reg, v, p, 0)).collect();
            let lat = outs.iter().map(|o| o.latency_ms).sum::<f64>() / 40.0;
            let en = outs.iter().map(|o| o.energy_mj).sum::<f64>() / 40.0;
            (lat, en)
        };
        let (l_perf, e_perf) = run(Governor::Performance);
        let (l_save, e_save) = run(Governor::Powersave);
        assert!(l_save > l_perf * 1.3, "powersave {l_save} vs perf {l_perf}");
        // energy per inference: powersave wins because power drops faster
        // than the clock (V^2 scaling)
        assert!(e_save < e_perf, "powersave energy {e_save} vs {e_perf}");
    }

    #[test]
    fn governor_default_is_schedutil() {
        let sim = Simulator::new(profiles::pixel7(), 1);
        assert_eq!(sim.governor, Governor::Schedutil);
        assert_eq!(sim.governor.name(), "schedutil");
    }
}
