//! The three target devices of the paper (Table 6), as calibrated
//! simulator profiles: Google Pixel 7 (high-end, Tensor G2), Samsung
//! Galaxy S20 FE (high-end, Exynos 990) and Samsung Galaxy A71 (mid-tier,
//! Snapdragon 730).
//!
//! Throughput figures are *effective* GFLOP/s chosen to reproduce the
//! structure of the paper's measurements (who wins per scheme, rough
//! ratios between engines and devices), not vendor peak numbers.

use super::{Engine, EnginePerf};

/// A simulated target device (one row of Table 6).
#[derive(Debug, Clone)]
pub struct Device {
    pub name: &'static str,
    pub launch: &'static str,
    pub soc: &'static str,
    pub ram_gb: f64,
    pub ram_mhz: u32,
    pub tdp_w: f64,
    /// Available compute engines (paper: CE_P7 = CE_S20 = {CPU,GPU,NPU},
    /// CE_A71 = {CPU,GPU,NPU,DSP}).
    pub engines: Vec<Engine>,
    /// A71's Hexagon Tensor Accelerator only runs fixed-point CNNs.
    pub npu_integer_only: bool,
    perf: [Option<EnginePerf>; 4],
    /// Ambient + throttling parameters (°C).
    pub ambient_c: f64,
    pub throttle_c: f64,
}

impl Device {
    pub fn perf(&self, engine: Engine) -> &EnginePerf {
        self.perf[engine.index()]
            .as_ref()
            .unwrap_or_else(|| panic!("{} has no {}", self.name, engine.name()))
    }

    pub fn has_engine(&self, engine: Engine) -> bool {
        self.engines.contains(&engine)
    }

    /// Total RAM in bytes.
    pub fn ram_bytes(&self) -> f64 {
        self.ram_gb * 1e9
    }
}

fn perf_slot(
    cpu: EnginePerf,
    gpu: EnginePerf,
    npu: Option<EnginePerf>,
    dsp: Option<EnginePerf>,
) -> [Option<EnginePerf>; 4] {
    [Some(cpu), Some(gpu), npu, dsp]
}

/// Google Pixel 7 — Tensor G2: 2x2.85 X1 + 2x2.35 A76 + 4x1.80 A55,
/// Mali-G710 MP7, mobile TPU, 8 GB LPDDR5-3200, 7 W TDP.
pub fn pixel7() -> Device {
    Device {
        name: "Pixel 7",
        launch: "2022-10",
        soc: "Tensor G2",
        ram_gb: 8.0,
        ram_mhz: 3200,
        tdp_w: 7.0,
        engines: vec![Engine::Cpu, Engine::Gpu, Engine::Npu],
        npu_integer_only: false,
        perf: perf_slot(
            EnginePerf {
                f32_gflops: 22.0, f16_gflops: 24.0, int8_gflops: 40.0,
                overhead_ms: 0.25, noise_sigma: 0.08, power_w: 1.1,
                transformer_factor: 0.85,
            },
            EnginePerf {
                f32_gflops: 85.0, f16_gflops: 160.0, int8_gflops: 70.0,
                overhead_ms: 1.1, noise_sigma: 0.05, power_w: 3.6,
                transformer_factor: 0.7,
            },
            Some(EnginePerf {
                f32_gflops: 60.0, f16_gflops: 140.0, int8_gflops: 290.0,
                overhead_ms: 1.6, noise_sigma: 0.04, power_w: 2.2,
                transformer_factor: 0.45,
            }),
            None,
        ),
        ambient_c: 28.0,
        throttle_c: 46.0,
    }
}

/// Samsung Galaxy S20 FE — Exynos 990: 2x2.73 M5 + 2x2.50 A76 + 4x2.0 A55,
/// Mali-G77 MP11, Exynos NPU (EDEN), 6 GB LPDDR5-2750, 9 W TDP.
pub fn galaxy_s20() -> Device {
    Device {
        name: "Galaxy S20 FE",
        launch: "2020-10",
        soc: "Exynos 990",
        ram_gb: 6.0,
        ram_mhz: 2750,
        tdp_w: 9.0,
        engines: vec![Engine::Cpu, Engine::Gpu, Engine::Npu],
        npu_integer_only: false,
        perf: perf_slot(
            EnginePerf {
                f32_gflops: 17.0, f16_gflops: 18.5, int8_gflops: 30.0,
                overhead_ms: 0.3, noise_sigma: 0.09, power_w: 1.3,
                transformer_factor: 0.85,
            },
            EnginePerf {
                f32_gflops: 72.0, f16_gflops: 135.0, int8_gflops: 55.0,
                overhead_ms: 1.3, noise_sigma: 0.06, power_w: 4.1,
                transformer_factor: 0.7,
            },
            Some(EnginePerf {
                f32_gflops: 45.0, f16_gflops: 105.0, int8_gflops: 220.0,
                overhead_ms: 1.8, noise_sigma: 0.05, power_w: 2.4,
                transformer_factor: 0.4,
            }),
            None,
        ),
        ambient_c: 28.0,
        throttle_c: 44.0,
    }
}

/// Samsung Galaxy A71 — Snapdragon 730: 2x2.20 + 6x1.80 Kryo 470,
/// Adreno 618, Hexagon HTA (integer-only) + DSP, 6 GB LPDDR4-1866, 5 W.
pub fn galaxy_a71() -> Device {
    Device {
        name: "Galaxy A71",
        launch: "2020-01",
        soc: "Snapdragon 730",
        ram_gb: 6.0,
        ram_mhz: 1866,
        tdp_w: 5.0,
        engines: vec![Engine::Cpu, Engine::Gpu, Engine::Npu, Engine::Dsp],
        npu_integer_only: true,
        perf: perf_slot(
            EnginePerf {
                f32_gflops: 8.5, f16_gflops: 9.0, int8_gflops: 15.0,
                overhead_ms: 0.45, noise_sigma: 0.11, power_w: 0.9,
                transformer_factor: 0.85,
            },
            EnginePerf {
                f32_gflops: 36.0, f16_gflops: 62.0, int8_gflops: 28.0,
                overhead_ms: 1.8, noise_sigma: 0.08, power_w: 2.6,
                transformer_factor: 0.7,
            },
            Some(EnginePerf {
                f32_gflops: 0.0, f16_gflops: 0.0, int8_gflops: 190.0,
                overhead_ms: 2.2, noise_sigma: 0.05, power_w: 1.6,
                transformer_factor: 0.35,
            }),
            Some(EnginePerf {
                f32_gflops: 0.0, f16_gflops: 0.0, int8_gflops: 150.0,
                overhead_ms: 2.0, noise_sigma: 0.04, power_w: 1.2,
                transformer_factor: 0.35,
            }),
        ),
        ambient_c: 28.0,
        throttle_c: 42.0,
    }
}

/// All three paper devices.
pub fn all() -> Vec<Device> {
    vec![galaxy_a71(), galaxy_s20(), pixel7()]
}

/// Lookup by short name: "p7" | "s20" | "a71".
pub fn by_name(name: &str) -> Option<Device> {
    match name.to_ascii_lowercase().as_str() {
        "p7" | "pixel7" => Some(pixel7()),
        "s20" | "galaxys20" => Some(galaxy_s20()),
        "a71" | "galaxya71" => Some(galaxy_a71()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::zoo::registry::Family;
    use crate::zoo::Scheme;
    use crate::device::Proc;

    #[test]
    fn table6_engine_sets() {
        assert_eq!(pixel7().engines.len(), 3);
        assert_eq!(galaxy_s20().engines.len(), 3);
        assert_eq!(galaxy_a71().engines.len(), 4);
        assert!(galaxy_a71().has_engine(Engine::Dsp));
        assert!(!pixel7().has_engine(Engine::Dsp));
    }

    #[test]
    fn high_end_faster_than_mid_tier() {
        // same workload, same config: P7 and S20 beat A71 everywhere.
        let flops = 0.6e9;
        for engine in [Engine::Cpu, Engine::Gpu] {
            let l = |d: &Device| {
                d.perf(engine).latency_ms(
                    flops,
                    Proc::Cpu { threads: 4, xnnpack: true },
                    Scheme::Fp32,
                    Family::Cnn,
                )
            };
            assert!(l(&pixel7()) < l(&galaxy_a71()), "{}", engine.name());
            assert!(l(&galaxy_s20()) < l(&galaxy_a71()), "{}", engine.name());
        }
    }

    #[test]
    fn npu_dominates_integer_cnns() {
        // EfficientNet Lite0 FFX8: NPU >> CPU on every device (the premise
        // behind Table 7/8's designs).
        for d in all() {
            let npu = d.perf(Engine::Npu).latency_ms(
                0.77e9, Proc::Npu, Scheme::Ffx8, Family::Cnn);
            let cpu1 = d.perf(Engine::Cpu).latency_ms(
                0.77e9, Proc::Cpu { threads: 1, xnnpack: false },
                Scheme::Ffx8, Family::Cnn);
            assert!(npu < cpu1, "{}", d.name);
        }
    }

    #[test]
    fn gpu_prefers_fp16() {
        for d in all() {
            let p = d.perf(Engine::Gpu);
            assert!(p.f16_gflops > p.f32_gflops, "{}", d.name);
        }
    }

    #[test]
    fn ram_capacity_matches_table6() {
        assert_eq!(pixel7().ram_gb, 8.0);
        assert_eq!(galaxy_s20().ram_gb, 6.0);
        assert_eq!(galaxy_a71().ram_gb, 6.0);
    }
}
