//! Summary scaling helpers for contention-adjusted statistics.
//!
//! Multi-DNN evaluation scales a solo-profiled latency distribution by a
//! deterministic contention factor instead of re-profiling every point of
//! the M-dimensional product space — the paper itself notes exhaustive
//! multi-DNN profiling is infeasible (§4.2, §8). Scaling a distribution
//! by c > 0 scales its mean, std, min, max and every percentile by c,
//! which is exactly what the time-slicing contention model predicts.

use crate::util::Summary;

/// Scale every sample of a summary by `c` (c > 0).
pub fn scale(s: &Summary, c: f64) -> Summary {
    s.scaled(c)
}

/// Contention factor for an engine shared by `k` *other* DNNs: near-linear
/// time slicing (paper §2.1.3), matching the simulator's co-location model.
pub fn contention_factor(co_located: usize) -> f64 {
    ((co_located + 1) as f64).powf(0.95)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scaling_scales_moments() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        let t = scale(&s, 2.0);
        assert!((t.mean - 2.0 * s.mean).abs() < 1e-9);
        assert!((t.std - 2.0 * s.std).abs() < 1e-9);
        assert!((t.max - 2.0 * s.max).abs() < 1e-9);
        assert!((t.percentile(50.0) - 2.0 * s.percentile(50.0)).abs() < 1e-9);
    }

    #[test]
    fn contention_monotone_and_identity_at_zero() {
        assert_eq!(contention_factor(0), 1.0);
        assert!(contention_factor(1) > 1.8 && contention_factor(1) <= 2.0);
        assert!(contention_factor(2) > contention_factor(1));
    }
}
