//! Objective-function evaluation by profiling (paper §4.2, §6.4).
//!
//! Device-dependent metrics (latency, energy, memory) cannot be derived
//! analytically; CARIn profiles every (model variant, processor config)
//! pair on the target device: 5 warm-up runs, 100 measured runs, and an
//! idle period between sets to keep the die temperature consistent.
//! Here the "device" is the behavioural simulator ([`crate::device`]);
//! the end-to-end example additionally substitutes *measured* PJRT
//! latencies for the CPU reference point (see `examples/e2e_serving.rs`).

pub mod predictor;
pub mod stats;

use std::collections::HashMap;

use crate::device::{Device, Proc, Simulator};
use crate::moo::space::Config;
use crate::util::Summary;
use crate::zoo::{Registry, Variant};

/// Paper §6.4 profiling protocol.
pub const WARMUP_RUNS: usize = 5;
pub const MEASURE_RUNS: usize = 100;
/// Idle gap between profiling sets, seconds (paper uses 2 minutes).
pub const IDLE_BETWEEN_SETS_S: f64 = 120.0;

/// Profiled statistics of one (variant, proc) execution configuration.
#[derive(Debug, Clone)]
pub struct ProfiledPoint {
    pub latency_ms: Summary,
    pub energy_mj: Summary,
    pub mf_bytes: f64,
}

/// Cache of profiled points, keyed by execution configuration.
#[derive(Debug, Clone, Default)]
pub struct ProfileCache {
    map: HashMap<(Variant, Proc), ProfiledPoint>,
}

impl ProfileCache {
    pub fn get(&self, variant: Variant, proc: Proc) -> &ProfiledPoint {
        self.map.get(&(variant, proc)).unwrap_or_else(|| {
            panic!("unprofiled configuration {variant:?} on {proc:?}")
        })
    }

    pub fn contains(&self, variant: Variant, proc: Proc) -> bool {
        self.map.contains_key(&(variant, proc))
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn insert(&mut self, variant: Variant, proc: Proc, point: ProfiledPoint) {
        self.map.insert((variant, proc), point);
    }
}

/// Profile one execution configuration on a (reset) simulator.
pub fn profile_one(
    reg: &Registry,
    sim: &mut Simulator,
    variant: Variant,
    proc: Proc,
) -> ProfiledPoint {
    for _ in 0..WARMUP_RUNS {
        sim.run_inference(reg, variant, proc, 0);
    }
    let mut lat = Vec::with_capacity(MEASURE_RUNS);
    let mut en = Vec::with_capacity(MEASURE_RUNS);
    for _ in 0..MEASURE_RUNS {
        let o = sim.run_inference(reg, variant, proc, 0);
        lat.push(o.latency_ms);
        en.push(o.energy_mj);
    }
    ProfiledPoint {
        latency_ms: Summary::of(&lat),
        energy_mj: Summary::of(&en),
        mf_bytes: sim.footprint_bytes(reg, variant, proc),
    }
}

/// Profile every unique (variant, proc) appearing in `space`.
pub fn profile_space(
    reg: &Registry,
    device: &Device,
    space: &[Config],
    seed: u64,
) -> ProfileCache {
    let mut cache = ProfileCache::default();
    let mut sim = Simulator::new(device.clone(), seed);
    for cfg in space {
        for a in &cfg.assignments {
            if cache.contains(a.variant, a.proc) {
                continue;
            }
            let point = profile_one(reg, &mut sim, a.variant, a.proc);
            // §6.4: cool-down between sets keeps temperatures consistent.
            sim.idle(IDLE_BETWEEN_SETS_S);
            cache.insert(a.variant, a.proc, point);
        }
    }
    cache
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::zoo::registry::Task;
    use crate::zoo::Scheme;

    #[test]
    fn profile_one_has_100_samples() {
        let reg = Registry::paper();
        let mut sim = Simulator::new(profiles::galaxy_s20(), 1);
        let v = Variant { model: reg.find("MobileNet V2 1.0").unwrap(), scheme: Scheme::Fp32 };
        let p = profile_one(&reg, &mut sim, v, Proc::Gpu);
        assert_eq!(p.latency_ms.n, MEASURE_RUNS);
        assert!(p.latency_ms.mean > 0.0);
        assert!(p.energy_mj.mean > 0.0);
        assert!(p.mf_bytes > 0.0);
    }

    #[test]
    fn profile_space_covers_every_assignment() {
        let reg = Registry::paper();
        let dev = profiles::galaxy_s20();
        let space: Vec<Config> = crate::moo::space::task_space(&reg, &dev, Task::AudioCls)
            .into_iter()
            .map(|a| Config { assignments: vec![a] })
            .collect();
        let cache = profile_space(&reg, &dev, &space, 3);
        for cfg in &space {
            assert!(cache.contains(cfg.assignments[0].variant, cfg.assignments[0].proc));
        }
    }

    #[test]
    fn faster_engine_profiles_faster() {
        let reg = Registry::paper();
        let dev = profiles::galaxy_s20();
        let mut sim = Simulator::new(dev, 5);
        let v = Variant {
            model: reg.find("EfficientNet Lite0").unwrap(),
            scheme: Scheme::Ffx8,
        };
        let cpu1 = profile_one(&reg, &mut sim, v,
            Proc::Cpu { threads: 1, xnnpack: false });
        sim.idle(IDLE_BETWEEN_SETS_S);
        let npu = profile_one(&reg, &mut sim, v, Proc::Npu);
        assert!(npu.latency_ms.mean < cpu1.latency_ms.mean);
    }
}
