//! Latency/energy prediction (paper §8): exhaustive on-device profiling
//! is the paper's acknowledged scalability limit; related work (nn-Meter,
//! CoDL, HERTI) replaces it with learned predictors. This module fits a
//! per-(engine, scheme-class, family) linear model
//!
//! `latency_ms ≈ a * GFLOPs + b`
//!
//! by least squares over a *subset* of profiled points and predicts the
//! rest, so a CARIn deployment can profile O(engines) configurations
//! instead of O(|X|). The ablation bench quantifies the accuracy/cost
//! trade-off against full profiling.

use std::collections::HashMap;

use crate::device::{Engine, Proc};
use crate::profiler::{ProfileCache, ProfiledPoint};
use crate::util::Summary;
use crate::zoo::registry::Family;
use crate::zoo::{Registry, Scheme, Variant};

/// Key under which points share one linear model.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ModelKey {
    pub engine: Engine,
    pub integer: bool,
    pub family_transformer: bool,
}

fn key_of(reg: &Registry, v: Variant, proc: Proc) -> ModelKey {
    ModelKey {
        engine: proc.engine(),
        integer: v.scheme.is_integer(),
        family_transformer: matches!(
            reg.models[v.model].family,
            Family::Transformer
        ),
    }
}

/// CPU-scaling feature replicated from the perf model: the predictor
/// regresses over *normalised* work so one model covers all thread/XNNPACK
/// options.
fn cpu_norm(proc: Proc, scheme: Scheme) -> f64 {
    match proc {
        Proc::Cpu { threads, xnnpack } => {
            let t = (threads as f64).powf(0.72);
            let x = if xnnpack {
                if scheme.is_integer() { 2.0 } else { 1.5 }
            } else {
                1.0
            };
            t * x
        }
        _ => 1.0,
    }
}

/// A fitted latency predictor.
#[derive(Debug, Clone, Default)]
pub struct LatencyPredictor {
    /// (slope ms per normalised GFLOP, intercept ms, cv) per key.
    coeffs: HashMap<ModelKey, (f64, f64, f64)>,
}

impl LatencyPredictor {
    /// Fit from a set of profiled (variant, proc) points.
    pub fn fit(
        reg: &Registry,
        points: &[(Variant, Proc, ProfiledPoint)],
    ) -> LatencyPredictor {
        let mut groups: HashMap<ModelKey, Vec<(f64, f64, f64)>> = HashMap::new();
        for (v, proc, point) in points {
            let entry = &reg.models[v.model];
            let gflops = v.flops(reg) * entry.batch as f64 / 1e9
                / cpu_norm(*proc, v.scheme);
            groups.entry(key_of(reg, *v, *proc)).or_default().push((
                gflops,
                point.latency_ms.mean,
                point.latency_ms.cv(),
            ));
        }
        let mut coeffs = HashMap::new();
        for (k, pts) in groups {
            let n = pts.len() as f64;
            let sx: f64 = pts.iter().map(|p| p.0).sum();
            let sy: f64 = pts.iter().map(|p| p.1).sum();
            let sxx: f64 = pts.iter().map(|p| p.0 * p.0).sum();
            let sxy: f64 = pts.iter().map(|p| p.0 * p.1).sum();
            let denom = n * sxx - sx * sx;
            let (a, b) = if denom.abs() < 1e-12 || pts.len() < 2 {
                // degenerate: one sample — proportional model
                let p = &pts[0];
                (if p.0 > 0.0 { p.1 / p.0 } else { 0.0 }, 0.0)
            } else {
                let a = (n * sxy - sx * sy) / denom;
                let b = (sy - a * sx) / n;
                (a.max(0.0), b.max(0.0))
            };
            let cv = pts.iter().map(|p| p.2).sum::<f64>() / n;
            coeffs.insert(k, (a, b, cv));
        }
        LatencyPredictor { coeffs }
    }

    /// Predict the mean latency of an unprofiled configuration, ms.
    pub fn predict_mean(&self, reg: &Registry, v: Variant, proc: Proc) -> Option<f64> {
        let (a, b, _) = self.coeffs.get(&key_of(reg, v, proc))?;
        let entry = &reg.models[v.model];
        let gflops =
            v.flops(reg) * entry.batch as f64 / 1e9 / cpu_norm(proc, v.scheme);
        Some(a * gflops + b)
    }

    /// Synthesize a full profiled point (latency distribution via the
    /// group's typical coefficient of variation; energy via the device
    /// power model; memory analytically).
    pub fn predict_point(
        &self,
        reg: &Registry,
        device: &crate::device::Device,
        v: Variant,
        proc: Proc,
    ) -> Option<ProfiledPoint> {
        let (a, b, cv) = *self.coeffs.get(&key_of(reg, v, proc))?;
        let entry = &reg.models[v.model];
        let gflops =
            v.flops(reg) * entry.batch as f64 / 1e9 / cpu_norm(proc, v.scheme);
        let mean = a * gflops + b;
        // a deterministic synthetic distribution with matching mean/cv
        let std = mean * cv;
        let samples: Vec<f64> = (0..crate::profiler::MEASURE_RUNS)
            .map(|i| {
                let z = (i as f64 / (crate::profiler::MEASURE_RUNS - 1) as f64 - 0.5) * 3.46;
                (mean + std * z).max(mean * 0.2)
            })
            .collect();
        let power = device.perf(proc.engine()).power_w;
        let energy: Vec<f64> = samples.iter().map(|l| l * power).collect();
        Some(ProfiledPoint {
            latency_ms: Summary::of(&samples),
            energy_mj: Summary::of(&energy),
            mf_bytes: crate::device::memory::footprint_bytes(reg, v, proc),
        })
    }

    pub fn n_models(&self) -> usize {
        self.coeffs.len()
    }
}

/// Build a profile cache for `space` by profiling only `train_frac` of
/// the unique configurations and predicting the rest. Returns the cache
/// and the number of configurations actually profiled.
pub fn predicted_cache(
    reg: &Registry,
    device: &crate::device::Device,
    space: &[crate::moo::space::Config],
    train_frac: f64,
    seed: u64,
) -> (ProfileCache, usize) {
    // unique assignments
    let mut uniq: Vec<(Variant, Proc)> = Vec::new();
    for cfg in space {
        for a in &cfg.assignments {
            if !uniq.contains(&(a.variant, a.proc)) {
                uniq.push((a.variant, a.proc));
            }
        }
    }
    let mut rng = crate::util::Rng::new(seed);
    let mut idx: Vec<usize> = (0..uniq.len()).collect();
    rng.shuffle(&mut idx);
    let n_train = ((uniq.len() as f64 * train_frac).ceil() as usize)
        .clamp(1, uniq.len());

    let mut sim = crate::device::Simulator::new(device.clone(), seed);
    let mut train: Vec<(Variant, Proc, ProfiledPoint)> = Vec::new();
    for &i in idx.iter().take(n_train) {
        let (v, p) = uniq[i];
        let point = crate::profiler::profile_one(reg, &mut sim, v, p);
        sim.idle(crate::profiler::IDLE_BETWEEN_SETS_S);
        train.push((v, p, point));
    }
    let predictor = LatencyPredictor::fit(reg, &train);

    let mut cache = ProfileCache::default();
    for (v, p, point) in &train {
        cache.insert(*v, *p, point.clone());
    }
    for &(v, p) in &uniq {
        if cache.contains(v, p) {
            continue;
        }
        let point = predictor
            .predict_point(reg, device, v, p)
            .unwrap_or_else(|| {
                // key unseen in training: fall back to profiling
                let pt = crate::profiler::profile_one(reg, &mut sim, v, p);
                sim.idle(crate::profiler::IDLE_BETWEEN_SETS_S);
                pt
            });
        cache.insert(v, p, point);
    }
    (cache, n_train)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;
    use crate::device::profiles;
    use crate::zoo::registry::Task;

    fn training_points(
        reg: &Registry,
        dev: &crate::device::Device,
    ) -> Vec<(Variant, Proc, ProfiledPoint)> {
        let mut sim = crate::device::Simulator::new(dev.clone(), 4);
        let mut out = Vec::new();
        for a in crate::moo::space::task_space(reg, dev, Task::ImageCls) {
            let pt = crate::profiler::profile_one(reg, &mut sim, a.variant, a.proc);
            sim.idle(crate::profiler::IDLE_BETWEEN_SETS_S);
            out.push((a.variant, a.proc, pt));
        }
        out
    }

    #[test]
    fn predictor_accuracy_within_20_percent() {
        let reg = Registry::paper();
        let dev = profiles::galaxy_s20();
        let points = training_points(&reg, &dev);
        // leave-half-out evaluation
        let (train, test): (Vec<_>, Vec<_>) =
            points.iter().cloned().enumerate().fold(
                (Vec::new(), Vec::new()),
                |(mut tr, mut te), (i, p)| {
                    if i % 2 == 0 { tr.push(p) } else { te.push(p) }
                    (tr, te)
                },
            );
        let pred = LatencyPredictor::fit(&reg, &train);
        let mut errs = Vec::new();
        for (v, p, point) in &test {
            if let Some(m) = pred.predict_mean(&reg, *v, *p) {
                errs.push((m - point.latency_ms.mean).abs() / point.latency_ms.mean);
            }
        }
        assert!(!errs.is_empty());
        let mape = errs.iter().sum::<f64>() / errs.len() as f64;
        assert!(mape < 0.20, "MAPE {mape:.3}");
    }

    #[test]
    fn predicted_cache_covers_space_and_profiles_fraction() {
        let reg = Registry::paper();
        let dev = profiles::galaxy_a71();
        let p = config::use_case("uc2", &reg, &dev).unwrap();
        let (cache, n_train) = predicted_cache(&reg, &dev, &p.space, 0.3, 6);
        for cfg in &p.space {
            for a in &cfg.assignments {
                assert!(cache.contains(a.variant, a.proc));
            }
        }
        assert!(n_train < cache.len(), "{n_train} !< {}", cache.len());
    }

    #[test]
    fn rass_on_predicted_cache_picks_near_optimal_design() {
        // the headline of §8: prediction should preserve the *decision*,
        // not just the numbers. Solve UC1 with full profiling and with a
        // 30%-profiled predicted cache; the predicted d0's true optimality
        // must be within 25% of the fully-profiled d0.
        let reg = Registry::paper();
        let dev = profiles::galaxy_s20();
        let full = config::use_case("uc1", &reg, &dev).unwrap();
        let full_sol = crate::moo::rass::solve(&full);

        let (cache, _) = predicted_cache(&reg, &dev, &full.space, 0.3, 9);
        let approx = crate::moo::Problem {
            name: "uc1-pred".into(),
            tasks: full.tasks.clone(),
            device: full.device.clone(),
            registry: full.registry.clone(),
            objectives: full.objectives.clone(),
            constraints: full.constraints.clone(),
            space: full.space.clone(),
            cache,
        };
        let approx_sol = crate::moo::rass::solve(&approx);
        // evaluate the predicted pick under the TRUE cache
        let true_opt = crate::moo::baselines::optimality_of(
            &full,
            &approx_sol.designs[0].config,
        );
        assert!(
            true_opt >= full_sol.designs[0].optimality * 0.75,
            "predicted design true-opt {true_opt:.3} vs full {:.3}",
            full_sol.designs[0].optimality
        );
    }
}
