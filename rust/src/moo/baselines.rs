//! Comparison methods of the evaluation (paper §7.1.1): the OODIn
//! weighted-sum solver, the single-architecture baselines (B-A / B-S),
//! the device-transferred baseline and the multi-DNN-unaware baseline.

use std::time::Instant;

use super::optimality::{optimalities, ObjectiveStats};
use super::space::{Assignment, Config};
use super::Problem;

/// Result of a baseline: its chosen configuration (if it produced a
/// feasible one) and its solve time.
#[derive(Debug, Clone)]
pub struct BaselineResult {
    pub config: Option<Config>,
    pub solve_time: std::time::Duration,
    pub label: String,
}

impl BaselineResult {
    fn some(label: &str, config: Config, t0: Instant) -> Self {
        BaselineResult {
            config: Some(config),
            solve_time: t0.elapsed(),
            label: label.to_string(),
        }
    }

    fn none(label: &str, t0: Instant) -> Self {
        BaselineResult { config: None, solve_time: t0.elapsed(), label: label.to_string() }
    }
}

/// OODIn (the authors' prior framework): maximise the weighted sum of
/// min-max-normalised objectives over the constrained space. Solves from
/// scratch on every invocation — Table 9 measures exactly this time.
pub fn oodin(problem: &Problem) -> BaselineResult {
    let t0 = Instant::now();
    let feasible: Vec<&Config> =
        problem.space.iter().filter(|x| problem.feasible(x)).collect();
    if feasible.is_empty() {
        return BaselineResult::none("OODIn", t0);
    }
    let vectors: Vec<Vec<f64>> =
        feasible.iter().map(|x| problem.objective_vector(x)).collect();
    let best = weighted_sum_argmax(problem, &vectors);
    BaselineResult::some("OODIn", feasible[best].clone(), t0)
}

/// The weighted-sum core used by OODIn — exposed separately so Table 9
/// can time it over synthetic spaces of arbitrary dimension.
///
/// Faithful to the paper's critique (§7.1.1): OODIn normalises each
/// objective by its maximum magnitude only, which "fails to account for
/// the inherent scale discrepancies among the diverse objective
/// functions" — an objective with a narrow relative range (e.g. accuracy
/// spanning 71–81%) contributes almost nothing next to one spanning
/// orders of magnitude, unless the user hand-tunes weights.
pub fn weighted_sum_argmax(problem: &Problem, vectors: &[Vec<f64>]) -> usize {
    let n_obj = problem.objectives.len();
    let mut max_abs = vec![1e-24_f64; n_obj];
    for v in vectors {
        for i in 0..n_obj {
            max_abs[i] = max_abs[i].max(v[i].abs());
        }
    }
    let mut best = 0usize;
    let mut best_score = f64::NEG_INFINITY;
    for (k, v) in vectors.iter().enumerate() {
        let mut score = 0.0;
        for i in 0..n_obj {
            let norm = v[i] / max_abs[i]; // scale-only normalisation
            let norm = if problem.objectives[i].metric.higher_is_better() {
                norm
            } else {
                1.0 - norm
            };
            score += problem.objectives[i].weight * norm;
        }
        if score > best_score {
            best_score = score;
            best = k;
        }
    }
    best
}

/// Single-architecture baseline (B-A / B-S): commit to one model —
/// highest fp32 accuracy (B-A) or smallest size (B-S) — and pick its best
/// feasible execution configuration by optimality computed over the full
/// constrained space (so the comparison shares CARIn's metric).
pub fn single_architecture(problem: &Problem, best_accuracy: bool) -> BaselineResult {
    let label = if best_accuracy { "B-A" } else { "B-S" };
    let t0 = Instant::now();
    // choose the anchor model per task
    let reg = &problem.registry;
    let mut anchors = Vec::new();
    for &task in &problem.tasks {
        let candidates = reg.for_task(task);
        let pick = if best_accuracy {
            candidates.iter().copied().max_by(|&a, &b| {
                reg.models[a].accuracy[0]
                    .partial_cmp(&reg.models[b].accuracy[0])
                    .unwrap()
            })
        } else {
            candidates.iter().copied().min_by(|&a, &b| {
                reg.models[a]
                    .mparams
                    .partial_cmp(&reg.models[b].mparams)
                    .unwrap()
            })
        };
        anchors.push(pick.expect("task without models"));
    }
    // restrict the feasible space to configs using only the anchor models
    let feasible: Vec<Config> = problem
        .space
        .iter()
        .filter(|x| {
            x.assignments
                .iter()
                .zip(&anchors)
                .all(|(a, &m)| a.variant.model == m)
                && problem.feasible(x)
        })
        .cloned()
        .collect();
    if feasible.is_empty() {
        return BaselineResult::none(label, t0);
    }
    let opts = optimalities(problem, &feasible);
    let best = opts
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap();
    BaselineResult::some(label, feasible[best].clone(), t0)
}

/// Transferred baseline: solve the problem on `source` and deploy the
/// winning design on `problem`'s device. Returns `None` when the source
/// design is inapplicable (engine or scheme unavailable) or infeasible on
/// the target.
pub fn transferred(problem: &Problem, source: &Problem) -> BaselineResult {
    let label = format!("T_{}", source.device.name);
    let t0 = Instant::now();
    let src = super::rass::solve(source);
    let cfg = src.designs[0].config.clone();
    // applicability: target must expose the same space point
    if !problem.space.iter().any(|x| *x == cfg) {
        return BaselineResult::none(&label, t0);
    }
    if !problem.feasible(&cfg) {
        return BaselineResult::none(&label, t0);
    }
    BaselineResult { config: Some(cfg), solve_time: t0.elapsed(), label }
}

/// Multi-DNN-unaware baseline: decompose an M-task problem into M
/// independent single-task problems, solve each with CARIn's optimality
/// (ignoring contention), then concatenate the winners.
pub fn multi_dnn_unaware(problem: &Problem) -> BaselineResult {
    let t0 = Instant::now();
    let mut picks: Vec<Assignment> = Vec::new();
    for t in 0..problem.tasks.len() {
        // per-task sub-space: this task's assignments, evaluated solo
        let mut seen = Vec::new();
        for cfg in &problem.space {
            let a = cfg.assignments[t];
            if !seen.contains(&a) {
                seen.push(a);
            }
        }
        let solo_cfgs: Vec<Config> =
            seen.iter().map(|&a| Config { assignments: vec![a] }).collect();
        // single-task projection of the problem
        let sub = Problem {
            name: format!("{}-task{}", problem.name, t),
            tasks: vec![problem.tasks[t]],
            device: problem.device.clone(),
            registry: problem.registry.clone(),
            objectives: problem
                .objectives
                .iter()
                .filter(|o| o.task.is_none() || o.task == Some(t))
                .map(|o| {
                    let mut o = *o;
                    o.task = None;
                    o
                })
                .collect(),
            constraints: problem
                .constraints
                .iter()
                .filter(|c| c.task.is_none() || c.task == Some(t))
                .map(|c| {
                    let mut c = *c;
                    c.task = None;
                    c
                })
                .collect(),
            space: solo_cfgs.clone(),
            cache: problem.cache.clone(),
        };
        let feasible: Vec<Config> =
            sub.space.iter().filter(|x| sub.feasible(x)).cloned().collect();
        if feasible.is_empty() {
            return BaselineResult::none("unaware", t0);
        }
        let opts = optimalities(&sub, &feasible);
        let best = opts
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        picks.push(feasible[best].assignments[0]);
    }
    let combined = Config { assignments: picks };
    // the combined config may be infeasible under contention — that *is*
    // the point of the comparison; report it only if the target space
    // contains it and it satisfies constraints.
    if !problem.feasible(&combined) {
        return BaselineResult::none("unaware", t0);
    }
    BaselineResult::some("unaware", combined, t0)
}

/// Optimality of a baseline's pick measured in `problem`'s objective
/// space (shared stats with the feasible set, so numbers are comparable
/// across methods — this is what Figures 3–6 plot).
pub fn optimality_of(problem: &Problem, cfg: &Config) -> f64 {
    let feasible: Vec<Config> =
        problem.space.iter().filter(|x| problem.feasible(x)).cloned().collect();
    let vectors: Vec<Vec<f64>> =
        feasible.iter().map(|x| problem.objective_vector(x)).collect();
    let stats = ObjectiveStats::from_vectors(problem, &vectors);
    stats.optimality(&problem.objective_vector(cfg))
}

/// Restrict a problem to configurations whose engine set is exactly
/// `engines` — used by Figures 3–6 which report optimality per processor
/// (single-DNN) / processor combination (multi-DNN).
pub fn restrict_to_engines(problem: &Problem, engines: &[crate::device::Engine]) -> Problem {
    let mut es: Vec<_> = engines.to_vec();
    es.sort();
    Problem {
        name: format!("{}@{:?}", problem.name, es),
        tasks: problem.tasks.clone(),
        device: problem.device.clone(),
        registry: problem.registry.clone(),
        objectives: problem.objectives.clone(),
        constraints: problem.constraints.clone(),
        space: problem
            .space
            .iter()
            .filter(|x| x.engine_set() == es)
            .cloned()
            .collect(),
        cache: problem.cache.clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;
    use crate::device::profiles;
    use crate::zoo::Registry;

    #[test]
    fn oodin_produces_feasible_pick() {
        let p = config::use_case("uc1", &Registry::paper(), &profiles::galaxy_s20())
            .unwrap();
        let r = oodin(&p);
        let cfg = r.config.expect("OODIn found nothing");
        assert!(p.feasible(&cfg));
        assert!(r.solve_time.as_nanos() > 0);
    }

    #[test]
    fn rass_beats_or_matches_baselines_on_optimality() {
        let p = config::use_case("uc1", &Registry::paper(), &profiles::galaxy_s20())
            .unwrap();
        let rass_sol = super::super::rass::solve(&p);
        let d0_opt = rass_sol.designs[0].optimality;
        for r in [
            oodin(&p),
            single_architecture(&p, true),
            single_architecture(&p, false),
        ] {
            if let Some(cfg) = r.config {
                let o = optimality_of(&p, &cfg);
                assert!(
                    d0_opt >= o - 1e-9,
                    "{} beat RASS: {o} > {d0_opt}",
                    r.label
                );
            }
        }
    }

    #[test]
    fn single_arch_anchors_one_model() {
        let p = config::use_case("uc2", &Registry::paper(), &profiles::pixel7()).unwrap();
        let r = single_architecture(&p, true);
        if let Some(cfg) = r.config {
            // B-A on UC2 anchors MobileBERT (highest fp32 accuracy)
            let name = p.registry.models[cfg.assignments[0].variant.model].name;
            assert_eq!(name, "MobileBERT-L24-H512");
        }
    }

    #[test]
    fn unaware_on_multi_dnn() {
        let p = config::use_case("uc3", &Registry::paper(), &profiles::galaxy_a71())
            .unwrap();
        let r = multi_dnn_unaware(&p);
        // the unaware baseline may or may not survive contention; when it
        // does, RASS must still win.
        if let Some(cfg) = r.config {
            let rass_sol = super::super::rass::solve(&p);
            assert!(rass_sol.designs[0].optimality >= optimality_of(&p, &cfg) - 1e-9);
        }
    }

    #[test]
    fn transferred_between_devices() {
        let reg = Registry::paper();
        let p_target = config::use_case("uc1", &reg, &profiles::galaxy_a71()).unwrap();
        let p_source = config::use_case("uc1", &reg, &profiles::pixel7()).unwrap();
        let r = transferred(&p_target, &p_source);
        // either inapplicable (None) or feasible on the target
        if let Some(cfg) = r.config {
            assert!(p_target.feasible(&cfg));
        }
    }
}
