//! The multi-objective-optimisation framework of the paper (§4): SLO
//! modelling, decision-space construction, objective evaluation, the
//! RASS solver and the comparison baselines.

pub mod baselines;
pub mod eval;
pub mod nsga2;
pub mod optimality;
pub mod pareto;
pub mod rass;
pub mod space;

pub use eval::{ConfigMetrics, TaskMetrics};
pub use space::Config;

use crate::device::Device;
use crate::profiler::ProfileCache;
use crate::zoo::registry::Task;
use crate::zoo::Registry;

/// DNN-specific performance metrics (paper §4.1.1–4.1.2).
///
/// `F_single = {S, W, A, L, TP, E, MF}`;
/// `F_multi  = F_single(i) ∪ {STP, NTT, F}`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Metric {
    /// Model size (bytes stored).
    Size,
    /// Workload (FLOPs).
    Workload,
    /// Task accuracy (higher-better, task-specific units).
    Accuracy,
    /// Inference latency (ms).
    Latency,
    /// Throughput (samples/s).
    Throughput,
    /// Energy per inference (mJ).
    Energy,
    /// Memory footprint (bytes).
    MemFootprint,
    /// System throughput (multi-DNN; max = M).
    Stp,
    /// Normalised turnaround time (multi-DNN; >= 1, lower-better).
    Ntt,
    /// Fairness (multi-DNN; [0,1], higher-better).
    Fairness,
}

impl Metric {
    /// Whether larger values are better (drives the utopia point, §4.3.1).
    pub fn higher_is_better(self) -> bool {
        matches!(
            self,
            Metric::Accuracy | Metric::Throughput | Metric::Stp | Metric::Fairness
        )
    }

    pub fn name(self) -> &'static str {
        match self {
            Metric::Size => "S",
            Metric::Workload => "W",
            Metric::Accuracy => "A",
            Metric::Latency => "L",
            Metric::Throughput => "TP",
            Metric::Energy => "E",
            Metric::MemFootprint => "MF",
            Metric::Stp => "STP",
            Metric::Ntt => "NTT",
            Metric::Fairness => "F",
        }
    }
}

/// The statistic a narrow SLO bounds (paper §4.1: min/max/avg/std/p-th).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Statistic {
    Min,
    Max,
    Avg,
    Std,
    Percentile(f64),
}

impl Statistic {
    pub fn name(self) -> String {
        match self {
            Statistic::Min => "min".into(),
            Statistic::Max => "max".into(),
            Statistic::Avg => "avg".into(),
            Statistic::Std => "std".into(),
            Statistic::Percentile(p) => format!("p{p}"),
        }
    }
}

/// A broad SLO: `<min/max, p>` becomes an objective function (§4.1).
#[derive(Debug, Clone, Copy)]
pub struct Objective {
    pub metric: Metric,
    /// Statistic used for sampled metrics (Avg unless stated).
    pub stat: Statistic,
    /// Task index for per-task metrics in multi-DNN problems; `None`
    /// for system-level metrics (STP, NTT, F) or single-DNN problems.
    pub task: Option<usize>,
    /// User-supplied weight `w_i` in the optimality distance (§4.3.1).
    pub weight: f64,
}

impl Objective {
    pub fn new(metric: Metric) -> Objective {
        Objective { metric, stat: Statistic::Avg, task: None, weight: 1.0 }
    }

    pub fn stat(mut self, stat: Statistic) -> Objective {
        self.stat = stat;
        self
    }

    pub fn task(mut self, t: usize) -> Objective {
        self.task = Some(t);
        self
    }

    pub fn weight(mut self, w: f64) -> Objective {
        self.weight = w;
        self
    }

    pub fn describe(&self) -> String {
        let dir = if self.metric.higher_is_better() { "max" } else { "min" };
        match self.task {
            Some(t) => format!("{} {}({})[task{}]", dir, self.stat.name(), self.metric.name(), t),
            None => format!("{} {}({})", dir, self.stat.name(), self.metric.name()),
        }
    }
}

/// A narrow SLO: `<stat, p, v>` becomes an inequality constraint
/// `g(x) = stat(p)(x) - v <= 0` (or `v - stat(p)(x)` for higher-better
/// metrics) (§4.1).
#[derive(Debug, Clone, Copy)]
pub struct Constraint {
    pub metric: Metric,
    pub stat: Statistic,
    /// Task index; `None` applies the constraint to *every* task.
    pub task: Option<usize>,
    pub bound: f64,
}

impl Constraint {
    /// g(x) <= 0 iff satisfied.
    pub fn violation(&self, m: &ConfigMetrics) -> f64 {
        let worst: f64 = match self.task {
            Some(t) => m.value(self.metric, self.stat, Some(t)),
            None => {
                if m.tasks.len() == 1 || matches!(self.metric, Metric::Stp | Metric::Ntt | Metric::Fairness) {
                    m.value(self.metric, self.stat, None)
                } else {
                    // applies to every task: take the worst task
                    let vals = (0..m.tasks.len())
                        .map(|t| m.value(self.metric, self.stat, Some(t)));
                    if self.metric.higher_is_better() {
                        vals.fold(f64::INFINITY, f64::min)
                    } else {
                        vals.fold(f64::NEG_INFINITY, f64::max)
                    }
                }
            }
        };
        if worst.is_nan() {
            // NaN never satisfies <=, so the config is rejected — make the
            // silent rejection diagnosable without polluting stdout.
            crate::log_trace!("constraint {} saw NaN; config rejected", self.describe());
        }
        if self.metric.higher_is_better() {
            self.bound - worst
        } else {
            worst - self.bound
        }
    }

    pub fn satisfied(&self, m: &ConfigMetrics) -> bool {
        self.violation(m) <= 0.0
    }

    pub fn describe(&self) -> String {
        let op = if self.metric.higher_is_better() { ">=" } else { "<=" };
        let scope = match self.task {
            Some(t) => format!("[task{t}]"),
            None => String::new(),
        };
        format!("{}({}){} {} {}", self.stat.name(), self.metric.name(), scope, op, self.bound)
    }
}

/// A fully-formulated device-specific MOO problem (paper §4.1):
/// decision space, objectives, constraints and the profile cache that
/// backs objective evaluation.
pub struct Problem {
    pub name: String,
    pub tasks: Vec<Task>,
    pub device: Device,
    pub registry: Registry,
    pub objectives: Vec<Objective>,
    pub constraints: Vec<Constraint>,
    /// Enumerated decision space X (before constraints).
    pub space: Vec<Config>,
    pub cache: ProfileCache,
}

impl Problem {
    pub fn is_multi(&self) -> bool {
        self.tasks.len() > 1
    }

    /// Evaluate every objective for configuration `x` (paper line 8 of
    /// Algorithm 1). Returns the objective vector in declaration order.
    pub fn objective_vector(&self, x: &Config) -> Vec<f64> {
        self.objective_vector_of(&self.metrics(x))
    }

    /// Objective vector from pre-evaluated metrics (the solver hot path
    /// evaluates each configuration exactly once and reuses the metrics
    /// for feasibility, objectives and the d_m/d_w searches).
    pub fn objective_vector_of(&self, m: &ConfigMetrics) -> Vec<f64> {
        self.objectives
            .iter()
            .map(|o| m.value(o.metric, o.stat, o.task))
            .collect()
    }

    /// Constraint check on pre-evaluated metrics.
    pub fn feasible_metrics(&self, m: &ConfigMetrics) -> bool {
        self.constraints.iter().all(|c| c.satisfied(m))
    }

    pub fn metrics(&self, x: &Config) -> ConfigMetrics {
        eval::evaluate(self, x)
    }

    /// Does `x` satisfy every constraint?
    pub fn feasible(&self, x: &Config) -> bool {
        let m = self.metrics(x);
        self.constraints.iter().all(|c| c.satisfied(&m))
    }
}

/// Solver output (paper §4.3.4): the design set `D` and the switching
/// policy `SP` handed to the Runtime Manager.
#[derive(Debug, Clone)]
pub struct Solution {
    /// Selected designs; index 0 is the initial design `d_0`.
    pub designs: Vec<Design>,
    pub policy: rass::SwitchingPolicy,
    /// Size of the constrained space |X'| the solver worked on.
    pub feasible_count: usize,
    /// Solve wall-clock, for Table 9 comparisons.
    pub solve_time: std::time::Duration,
}

/// One design: a configuration plus its solver-time annotations.
#[derive(Debug, Clone)]
pub struct Design {
    pub config: Config,
    pub optimality: f64,
    /// Role labels: "d0", "d1", "d2", "dm", "dw" (a design may hold
    /// several roles when argmins coincide, e.g. `d_wm ≡ d_w`).
    pub roles: Vec<&'static str>,
}

impl Design {
    pub fn describe(&self, p: &Problem) -> String {
        format!(
            "{} (opt {:.3}) [{}]",
            self.config.describe(&p.registry),
            self.optimality,
            self.roles.join(",")
        )
    }
}
