//! Objective-function evaluation (Algorithm 1 line 8): turn a
//! configuration into the full metric set `F_single` / `F_multi` using
//! the profile cache, applying the contention model for multi-DNN
//! configurations.

use std::collections::HashMap;

use crate::profiler::stats::{contention_factor, scale};
use crate::util::Summary;

use super::space::{Assignment, Config};
use super::{Metric, Problem, Statistic};

/// All metrics of one task under a given configuration.
#[derive(Debug, Clone)]
pub struct TaskMetrics {
    pub size_bytes: f64,
    pub flops: f64,
    pub accuracy: f64,
    /// Contention-adjusted latency distribution (ms).
    pub latency_ms: Summary,
    /// Solo (single-DNN mode) mean latency — the `L_i^S` of §4.1.2.
    pub solo_latency_ms: f64,
    pub energy_mj: Summary,
    pub mf_bytes: f64,
    /// Normalised turnaround time `NTT_i = L_i^M / L_i^S >= 1`.
    pub ntt: f64,
    /// Samples per second (batch / avg latency).
    pub throughput: f64,
}

/// Metrics of a full configuration.
#[derive(Debug, Clone)]
pub struct ConfigMetrics {
    pub tasks: Vec<TaskMetrics>,
    /// System throughput `STP = Σ 1/NTT_i` (max = M).
    pub stp: f64,
    /// Fairness `F = min_{i,j} NP_i/NP_j ∈ [0, 1]`.
    pub fairness: f64,
}

impl ConfigMetrics {
    /// Extract a scalar for (metric, stat, task scope).
    ///
    /// Per-task metrics with `task == None` on multi-DNN problems
    /// aggregate across tasks: additive metrics (S, W, MF, TP) sum;
    /// the rest average. NTT with `task == None` follows the paper's
    /// "average or maximum NTT" convention via `stat`.
    pub fn value(&self, metric: Metric, stat: Statistic, task: Option<usize>) -> f64 {
        match metric {
            Metric::Stp => return self.stp,
            Metric::Fairness => return self.fairness,
            Metric::Ntt => {
                let vals: Vec<f64> = self.tasks.iter().map(|t| t.ntt).collect();
                return match stat {
                    Statistic::Max => vals.iter().copied().fold(f64::MIN, f64::max),
                    Statistic::Min => vals.iter().copied().fold(f64::MAX, f64::min),
                    _ => vals.iter().sum::<f64>() / vals.len() as f64,
                };
            }
            _ => {}
        }
        match task {
            Some(t) => self.task_value(t, metric, stat),
            None => {
                if self.tasks.len() == 1 {
                    self.task_value(0, metric, stat)
                } else {
                    let vals: Vec<f64> = (0..self.tasks.len())
                        .map(|t| self.task_value(t, metric, stat))
                        .collect();
                    match metric {
                        Metric::Size | Metric::Workload | Metric::MemFootprint
                        | Metric::Throughput => vals.iter().sum(),
                        _ => vals.iter().sum::<f64>() / vals.len() as f64,
                    }
                }
            }
        }
    }

    fn task_value(&self, t: usize, metric: Metric, stat: Statistic) -> f64 {
        let tm = &self.tasks[t];
        match metric {
            Metric::Size => tm.size_bytes,
            Metric::Workload => tm.flops,
            Metric::Accuracy => tm.accuracy,
            Metric::Latency => stat_of(&tm.latency_ms, stat),
            Metric::Throughput => tm.throughput,
            Metric::Energy => stat_of(&tm.energy_mj, stat),
            Metric::MemFootprint => tm.mf_bytes,
            Metric::Stp | Metric::Ntt | Metric::Fairness => unreachable!(),
        }
    }

    /// Total memory footprint across tasks (bytes).
    pub fn total_mf_bytes(&self) -> f64 {
        self.tasks.iter().map(|t| t.mf_bytes).sum()
    }

    /// Total workload across tasks (FLOPs).
    pub fn total_flops(&self) -> f64 {
        self.tasks.iter().map(|t| t.flops).sum()
    }
}

fn stat_of(s: &Summary, stat: Statistic) -> f64 {
    match stat {
        Statistic::Min => s.min,
        Statistic::Max => s.max,
        Statistic::Avg => s.mean,
        Statistic::Std => s.std,
        Statistic::Percentile(p) => s.percentile(p),
    }
}

/// Whether some objective or constraint reads the energy distribution
/// (solver-hot-path micro-optimisation: E is only materialised if so).
fn uses_energy(p: &Problem) -> bool {
    p.objectives
        .iter()
        .map(|o| o.metric)
        .chain(p.constraints.iter().map(|c| c.metric))
        .any(|m| m == Metric::Energy)
}

/// Metrics of one assignment sharing its engine with `co_located` other
/// tasks. Pure in `(assignment, co_located)` — which is exactly the
/// memoisation key [`evaluate_space`] dedups identical work by.
fn eval_task(p: &Problem, a: &Assignment, co_located: usize, uses_energy: bool) -> TaskMetrics {
    let point = p.cache.get(a.variant, a.proc);
    let entry = &p.registry.models[a.variant.model];
    let c = contention_factor(co_located);
    let latency = if c == 1.0 {
        point.latency_ms.clone()
    } else {
        scale(&point.latency_ms, c)
    };
    let throughput = entry.batch as f64 / latency.mean * 1000.0;
    let energy = if !uses_energy {
        Summary::of(&[point.energy_mj.mean * c])
    } else if c == 1.0 {
        point.energy_mj.clone()
    } else {
        scale(&point.energy_mj, c)
    };
    let accuracy = a.variant.accuracy(&p.registry).unwrap_or_else(|| {
        crate::log_trace!(
            "eval: {} model {} has no accuracy figure; objective sees NaN",
            p.name,
            entry.artifact
        );
        f64::NAN
    });
    TaskMetrics {
        size_bytes: a.variant.size_bytes(&p.registry),
        flops: a.variant.flops(&p.registry),
        accuracy,
        solo_latency_ms: point.latency_ms.mean,
        latency_ms: latency,
        energy_mj: energy,
        mf_bytes: point.mf_bytes,
        ntt: c,
        throughput,
    }
}

/// Derive the multi-DNN aggregates (STP, fairness) from per-task metrics.
fn finish(tasks: Vec<TaskMetrics>) -> ConfigMetrics {
    let nps: Vec<f64> = tasks.iter().map(|t| 1.0 / t.ntt).collect();
    let stp: f64 = nps.iter().sum();
    let fairness = if nps.len() < 2 {
        1.0
    } else {
        let min = nps.iter().copied().fold(f64::MAX, f64::min);
        let max = nps.iter().copied().fold(f64::MIN, f64::max);
        min / max
    };
    ConfigMetrics { tasks, stp, fairness }
}

/// Evaluate a configuration against a problem's profile cache.
pub fn evaluate(p: &Problem, x: &Config) -> ConfigMetrics {
    let ue = uses_energy(p);
    finish(
        x.assignments
            .iter()
            .enumerate()
            .map(|(t, a)| eval_task(p, a, x.co_located(t), ue))
            .collect(),
    )
}

/// Memoised variant: identical `(assignment, co-location)` pairs across
/// configurations share one metrics computation. In a multi-DNN product
/// space the same pair recurs |other tasks' space| times, so the memo
/// turns the dominant cost from O(space × tasks) into O(pairs).
fn evaluate_memo(
    p: &Problem,
    x: &Config,
    uses_energy: bool,
    memo: &mut HashMap<(Assignment, usize), TaskMetrics>,
) -> ConfigMetrics {
    let tasks = x
        .assignments
        .iter()
        .enumerate()
        .map(|(t, a)| {
            let key = (*a, x.co_located(t));
            memo.entry(key)
                .or_insert_with(|| eval_task(p, a, key.1, uses_energy))
                .clone()
        })
        .collect();
    finish(tasks)
}

/// Threshold below which threading overhead beats the parallel win.
const PARALLEL_EVAL_MIN: usize = 256;

/// Evaluate every configuration of the problem's decision space, chunked
/// across scoped threads with a per-thread memo. Deterministic: results
/// are written by space index and evaluation is pure, so the output is
/// bit-identical to the sequential loop regardless of thread count or
/// interleaving (`solve_is_deterministic` holds).
pub fn evaluate_space(p: &Problem) -> Vec<ConfigMetrics> {
    let n = p.space.len();
    let threads = std::thread::available_parallelism()
        .map(|t| t.get())
        .unwrap_or(1)
        .min(8);
    let ue = uses_energy(p);
    if threads <= 1 || n < PARALLEL_EVAL_MIN {
        let mut memo = HashMap::new();
        return p
            .space
            .iter()
            .map(|x| evaluate_memo(p, x, ue, &mut memo))
            .collect();
    }
    let chunk = n.div_ceil(threads);
    let mut out: Vec<Option<ConfigMetrics>> = vec![None; n];
    std::thread::scope(|s| {
        for (ci, cells) in out.chunks_mut(chunk).enumerate() {
            let lo = ci * chunk;
            s.spawn(move || {
                let mut memo = HashMap::new();
                for (j, cell) in cells.iter_mut().enumerate() {
                    *cell = Some(evaluate_memo(p, &p.space[lo + j], ue, &mut memo));
                }
            });
        }
    });
    out.into_iter().map(|m| m.expect("chunk evaluated")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;
    use crate::device::profiles;
    use crate::zoo::Registry;

    fn uc3_problem() -> Problem {
        config::use_case("uc3", &Registry::paper(), &profiles::galaxy_a71()).unwrap()
    }

    #[test]
    fn multi_metrics_invariants() {
        let p = uc3_problem();
        for x in p.space.iter().take(200) {
            let m = p.metrics(x);
            assert_eq!(m.tasks.len(), 2);
            for t in &m.tasks {
                assert!(t.ntt >= 1.0);
                assert!(t.latency_ms.mean >= t.solo_latency_ms * 0.999);
            }
            assert!(m.stp <= 2.0 + 1e-9);
            assert!((0.0..=1.0 + 1e-9).contains(&m.fairness));
            // STP = sum of 1/NTT
            let stp: f64 = m.tasks.iter().map(|t| 1.0 / t.ntt).sum();
            assert!((m.stp - stp).abs() < 1e-12);
        }
    }

    #[test]
    fn same_engine_colocation_reduces_stp() {
        let p = uc3_problem();
        let shared = p
            .space
            .iter()
            .find(|x| x.engine_set().len() == 1)
            .expect("some config shares an engine");
        let split = p
            .space
            .iter()
            .find(|x| x.engine_set().len() == 2)
            .expect("some config splits engines");
        let ms = p.metrics(shared);
        let mp = p.metrics(split);
        assert!(ms.stp < mp.stp);
        assert!(ms.tasks[0].ntt > 1.0);
        assert!((mp.tasks[0].ntt - 1.0).abs() < 1e-12);
    }

    #[test]
    fn evaluate_space_matches_sequential() {
        let p = uc3_problem();
        let all = evaluate_space(&p);
        assert_eq!(all.len(), p.space.len());
        for (x, m) in p.space.iter().zip(&all).step_by(97) {
            let seq = evaluate(&p, x);
            assert_eq!(m.stp.to_bits(), seq.stp.to_bits());
            assert_eq!(m.fairness.to_bits(), seq.fairness.to_bits());
            for (a, b) in m.tasks.iter().zip(&seq.tasks) {
                assert_eq!(a.latency_ms.mean.to_bits(), b.latency_ms.mean.to_bits());
                assert_eq!(a.mf_bytes.to_bits(), b.mf_bytes.to_bits());
                assert_eq!(a.throughput.to_bits(), b.throughput.to_bits());
            }
        }
    }

    #[test]
    fn aggregation_rules() {
        let p = uc3_problem();
        let x = &p.space[0];
        let m = p.metrics(x);
        let total_size = m.value(Metric::Size, Statistic::Avg, None);
        assert!(
            (total_size - (m.tasks[0].size_bytes + m.tasks[1].size_bytes)).abs() < 1e-6
        );
        let avg_acc = m.value(Metric::Accuracy, Statistic::Avg, None);
        assert!(
            (avg_acc - (m.tasks[0].accuracy + m.tasks[1].accuracy) / 2.0).abs() < 1e-9
        );
    }
}
