//! Pareto-dominance utilities: dominance tests, front extraction and the
//! fast non-dominated sort used by the NSGA-II reference solver.

/// Does `a` dominate `b`? (`higher[i]` gives each objective's direction.)
/// a dominates b iff a is no worse in every objective and strictly better
/// in at least one.
pub fn dominates(a: &[f64], b: &[f64], higher: &[bool]) -> bool {
    let mut strictly = false;
    for i in 0..a.len() {
        let (ai, bi) = if higher[i] { (a[i], b[i]) } else { (-a[i], -b[i]) };
        if ai < bi {
            return false;
        }
        if ai > bi {
            strictly = true;
        }
    }
    strictly
}

/// Indices of the non-dominated set (the Pareto front) of `vectors`.
pub fn front(vectors: &[Vec<f64>], higher: &[bool]) -> Vec<usize> {
    (0..vectors.len())
        .filter(|&i| {
            !vectors
                .iter()
                .enumerate()
                .any(|(j, v)| j != i && dominates(v, &vectors[i], higher))
        })
        .collect()
}

/// Fast non-dominated sort (Deb et al. 2002): returns the front index of
/// every solution (0 = Pareto-optimal).
pub fn non_dominated_sort(vectors: &[Vec<f64>], higher: &[bool]) -> Vec<usize> {
    let n = vectors.len();
    let mut dominated_by: Vec<Vec<usize>> = vec![Vec::new(); n]; // i dominates these
    let mut counts = vec![0usize; n]; // how many dominate i
    for i in 0..n {
        for j in (i + 1)..n {
            if dominates(&vectors[i], &vectors[j], higher) {
                dominated_by[i].push(j);
                counts[j] += 1;
            } else if dominates(&vectors[j], &vectors[i], higher) {
                dominated_by[j].push(i);
                counts[i] += 1;
            }
        }
    }
    let mut rank = vec![usize::MAX; n];
    let mut current: Vec<usize> =
        (0..n).filter(|&i| counts[i] == 0).collect();
    let mut level = 0;
    while !current.is_empty() {
        let mut next = Vec::new();
        for &i in &current {
            rank[i] = level;
            for &j in &dominated_by[i] {
                counts[j] -= 1;
                if counts[j] == 0 {
                    next.push(j);
                }
            }
        }
        current = next;
        level += 1;
    }
    rank
}

/// Crowding distance within one front (NSGA-II diversity pressure).
pub fn crowding(vectors: &[Vec<f64>], members: &[usize]) -> Vec<f64> {
    let m = members.len();
    let mut dist = vec![0.0f64; m];
    if m <= 2 {
        return vec![f64::INFINITY; m];
    }
    let n_obj = vectors[members[0]].len();
    for k in 0..n_obj {
        let mut order: Vec<usize> = (0..m).collect();
        order.sort_by(|&a, &b| {
            vectors[members[a]][k]
                .partial_cmp(&vectors[members[b]][k])
                .unwrap()
        });
        let lo = vectors[members[order[0]]][k];
        let hi = vectors[members[order[m - 1]]][k];
        dist[order[0]] = f64::INFINITY;
        dist[order[m - 1]] = f64::INFINITY;
        if (hi - lo).abs() < 1e-24 {
            continue;
        }
        for w in 1..m - 1 {
            dist[order[w]] += (vectors[members[order[w + 1]]][k]
                - vectors[members[order[w - 1]]][k])
                / (hi - lo);
        }
    }
    dist
}

#[cfg(test)]
mod tests {
    use super::*;

    const HI: [bool; 2] = [true, false]; // maximize first, minimize second

    #[test]
    fn dominance_basics() {
        assert!(dominates(&[2.0, 1.0], &[1.0, 2.0], &HI));
        assert!(!dominates(&[2.0, 3.0], &[1.0, 2.0], &HI)); // trade-off
        assert!(!dominates(&[1.0, 2.0], &[1.0, 2.0], &HI)); // equal
    }

    #[test]
    fn front_extraction() {
        let vs = vec![
            vec![3.0, 3.0], // front (best acc)
            vec![2.0, 1.0], // front (best lat among acc=2)
            vec![1.0, 1.0], // dominated by [2,1]
            vec![2.0, 2.0], // dominated by [2,1]
        ];
        let f = front(&vs, &HI);
        assert_eq!(f, vec![0, 1]);
    }

    #[test]
    fn nds_ranks_layers() {
        let vs = vec![
            vec![3.0, 1.0], // rank 0
            vec![2.0, 2.0], // rank 1 (dominated by none? [3,1] dominates it)
            vec![1.0, 3.0], // rank 2
        ];
        let r = non_dominated_sort(&vs, &HI);
        assert_eq!(r, vec![0, 1, 2]);
    }

    #[test]
    fn crowding_boundary_infinite() {
        let vs = vec![
            vec![1.0, 5.0],
            vec![2.0, 4.0],
            vec![3.0, 3.0],
            vec![4.0, 2.0],
        ];
        let members = vec![0, 1, 2, 3];
        let c = crowding(&vs, &members);
        assert!(c[0].is_infinite() && c[3].is_infinite());
        assert!(c[1].is_finite() && c[1] > 0.0);
    }
}
