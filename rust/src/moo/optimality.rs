//! Optimality metric (paper §4.3.1): the reciprocal of the scaled,
//! weighted Mahalanobis distance between a solution's objective vector
//! and the problem's utopia point.

use super::{Problem, space::Config};

/// Per-objective statistics over the (constrained) decision space,
/// needed by the distance: utopia component, variance, and min/max for
/// the d_max normaliser.
#[derive(Debug, Clone)]
pub struct ObjectiveStats {
    pub utopia: Vec<f64>,
    pub variance: Vec<f64>,
    pub min: Vec<f64>,
    pub max: Vec<f64>,
    pub weights: Vec<f64>,
    pub higher: Vec<bool>,
}

impl ObjectiveStats {
    /// Compute the stats from the objective vectors of the constrained
    /// space X'.
    pub fn from_vectors(problem: &Problem, vectors: &[Vec<f64>]) -> ObjectiveStats {
        let n_obj = problem.objectives.len();
        assert!(!vectors.is_empty(), "empty constrained space");
        let mut min = vec![f64::INFINITY; n_obj];
        let mut max = vec![f64::NEG_INFINITY; n_obj];
        let mut mean = vec![0.0; n_obj];
        for v in vectors {
            for i in 0..n_obj {
                min[i] = min[i].min(v[i]);
                max[i] = max[i].max(v[i]);
                mean[i] += v[i];
            }
        }
        for m in &mut mean {
            *m /= vectors.len() as f64;
        }
        let mut variance = vec![0.0; n_obj];
        for v in vectors {
            for i in 0..n_obj {
                let d = v[i] - mean[i];
                variance[i] += d * d;
            }
        }
        for v in &mut variance {
            *v /= vectors.len() as f64;
        }
        let higher: Vec<bool> =
            problem.objectives.iter().map(|o| o.metric.higher_is_better()).collect();
        let utopia: Vec<f64> = (0..n_obj)
            .map(|i| if higher[i] { max[i] } else { min[i] })
            .collect();
        let weights = problem.objectives.iter().map(|o| o.weight).collect();
        ObjectiveStats { utopia, variance, min, max, weights, higher }
    }

    /// Weighted Mahalanobis distance to the utopia point.
    pub fn distance(&self, v: &[f64]) -> f64 {
        let mut d2 = 0.0;
        for i in 0..v.len() {
            if self.variance[i] <= 1e-24 {
                continue; // constant objective contributes nothing
            }
            let diff = v[i] - self.utopia[i];
            d2 += self.weights[i] * self.weights[i] * diff * diff / self.variance[i];
        }
        d2.sqrt()
    }

    /// Maximum possible distance (paper's d_max normaliser).
    pub fn d_max(&self) -> f64 {
        let mut d2 = 0.0;
        for i in 0..self.utopia.len() {
            if self.variance[i] <= 1e-24 {
                continue;
            }
            let diff = self.max[i] - self.min[i];
            d2 += self.weights[i] * self.weights[i] * diff * diff / self.variance[i];
        }
        d2.sqrt()
    }

    /// `opt(x) = 1 / d_s(x) = d_max / d(x) ∈ [1, +inf)`.
    pub fn optimality(&self, v: &[f64]) -> f64 {
        let dmax = self.d_max();
        if dmax <= 1e-24 {
            return 1.0; // degenerate: all solutions identical
        }
        let d = self.distance(v);
        if d <= 1e-24 {
            f64::INFINITY // solution sits on the utopia point
        } else {
            dmax / d
        }
    }
}

/// Optimality of every configuration in `configs` under `problem`.
pub fn optimalities(problem: &Problem, configs: &[Config]) -> Vec<f64> {
    let vectors: Vec<Vec<f64>> =
        configs.iter().map(|c| problem.objective_vector(c)).collect();
    let stats = ObjectiveStats::from_vectors(problem, &vectors);
    vectors.iter().map(|v| stats.optimality(v)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;
    use crate::device::profiles;
    use crate::zoo::Registry;

    fn stats2(vectors: &[Vec<f64>], higher: Vec<bool>, weights: Vec<f64>) -> ObjectiveStats {
        // build a synthetic stats object without a Problem
        let n = vectors[0].len();
        let mut min = vec![f64::INFINITY; n];
        let mut max = vec![f64::NEG_INFINITY; n];
        let mut mean = vec![0.0; n];
        for v in vectors {
            for i in 0..n {
                min[i] = min[i].min(v[i]);
                max[i] = max[i].max(v[i]);
                mean[i] += v[i];
            }
        }
        for m in &mut mean {
            *m /= vectors.len() as f64;
        }
        let mut variance = vec![0.0; n];
        for v in vectors {
            for i in 0..n {
                variance[i] += (v[i] - mean[i]).powi(2);
            }
        }
        for v in &mut variance {
            *v /= vectors.len() as f64;
        }
        let utopia = (0..n).map(|i| if higher[i] { max[i] } else { min[i] }).collect();
        ObjectiveStats { utopia, variance, min, max, weights, higher }
    }

    #[test]
    fn utopia_solution_gets_infinite_optimality() {
        // one solution best in both objectives
        let vs = vec![vec![10.0, 1.0], vec![5.0, 2.0], vec![1.0, 3.0]];
        let s = stats2(&vs, vec![true, false], vec![1.0, 1.0]);
        assert!(s.optimality(&vs[0]).is_infinite());
        assert!(s.optimality(&vs[1]) > s.optimality(&vs[2]));
    }

    #[test]
    fn scale_invariance_of_mahalanobis() {
        // multiplying one objective by 1000 must not change the ordering
        let vs = vec![vec![10.0, 1.0], vec![8.0, 0.5], vec![2.0, 2.0]];
        let s1 = stats2(&vs, vec![true, false], vec![1.0, 1.0]);
        let o1: Vec<f64> = vs.iter().map(|v| s1.optimality(v)).collect();
        let vs2: Vec<Vec<f64>> =
            vs.iter().map(|v| vec![v[0] * 1000.0, v[1]]).collect();
        let s2 = stats2(&vs2, vec![true, false], vec![1.0, 1.0]);
        let o2: Vec<f64> = vs2.iter().map(|v| s2.optimality(v)).collect();
        for (a, b) in o1.iter().zip(&o2) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
    }

    #[test]
    fn weights_bias_the_ranking() {
        let vs = vec![vec![10.0, 10.0], vec![12.0, 2.0], vec![2.0, 12.0]];
        // both higher-better; weight objective 0 heavily
        let s = stats2(&vs, vec![true, true], vec![10.0, 0.1]);
        let o: Vec<f64> = vs.iter().map(|v| s.optimality(v)).collect();
        assert!(o[1] > o[2], "heavily weighted objective should dominate: {o:?}");
    }

    #[test]
    fn constant_objective_ignored() {
        let vs = vec![vec![1.0, 5.0], vec![1.0, 7.0]];
        let s = stats2(&vs, vec![false, true], vec![1.0, 1.0]);
        assert!(s.optimality(&vs[1]) > s.optimality(&vs[0]));
    }

    #[test]
    fn all_optimalities_at_least_one() {
        let reg = Registry::paper();
        let dev = profiles::galaxy_s20();
        let p = config::use_case("uc1", &reg, &dev).unwrap();
        let feasible: Vec<_> =
            p.space.iter().filter(|x| p.feasible(x)).cloned().collect();
        let opts = optimalities(&p, &feasible);
        assert!(!opts.is_empty());
        for o in opts {
            assert!(o >= 1.0 - 1e-9, "optimality {o} < 1");
        }
    }
}
