//! Decision-space construction (paper §4.1, Algorithm 1 lines 2–6).
//!
//! Single-DNN: `X = E = {⟨m, hw⟩}` — every (model variant, processor
//! config) pair valid on the target device.
//!
//! Multi-DNN: `X = E_1 × ... × E_M`. The full product can reach millions
//! of points (UC4); a *necessary-condition prefilter* drops per-task
//! configurations that violate latency/memory constraints even solo
//! (contention only makes them worse), which is sound because every
//! constrained metric is monotone in contention.

use crate::device::{compatible, Device, Proc};
use crate::zoo::registry::Task;
use crate::zoo::{Registry, Variant};

use super::{Constraint, Metric, Problem, Statistic};

/// One task's execution configuration `e = ⟨m, hw⟩` (paper Eq. 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Assignment {
    pub variant: Variant,
    pub proc: Proc,
}

/// A decision variable: one assignment per task (length 1 in single-DNN
/// problems).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Config {
    pub assignments: Vec<Assignment>,
}

impl Config {
    pub fn single(variant: Variant, proc: Proc) -> Config {
        Config { assignments: vec![Assignment { variant, proc }] }
    }

    /// Set of engines this configuration occupies (the key RASS groups
    /// designs by — §4.3.4 "model-to-processor mappings").
    pub fn engine_set(&self) -> Vec<crate::device::Engine> {
        let mut es: Vec<_> = self.assignments.iter().map(|a| a.proc.engine()).collect();
        es.sort();
        es.dedup();
        es
    }

    /// How many *other* tasks share the engine of task `t` (drives the
    /// contention model).
    pub fn co_located(&self, t: usize) -> usize {
        let e = self.assignments[t].proc.engine();
        self.assignments
            .iter()
            .enumerate()
            .filter(|(i, a)| *i != t && a.proc.engine() == e)
            .count()
    }

    pub fn describe(&self, reg: &Registry) -> String {
        let parts: Vec<String> = self
            .assignments
            .iter()
            .map(|a| format!("⟨{}, {}⟩", a.variant.describe(reg), a.proc.describe()))
            .collect();
        parts.join(" + ")
    }

    /// Total stored-model bytes (unique variants only; used by Table 10).
    pub fn storage_bytes(&self, reg: &Registry) -> f64 {
        let mut seen: Vec<Variant> = Vec::new();
        let mut total = 0.0;
        for a in &self.assignments {
            if !seen.contains(&a.variant) {
                seen.push(a.variant);
                total += a.variant.size_bytes(reg);
            }
        }
        total
    }
}

/// All processor configurations available on a device.
pub fn proc_options(device: &Device) -> Vec<Proc> {
    let mut out = Proc::cpu_options();
    for e in &device.engines {
        match e {
            crate::device::Engine::Gpu => out.push(Proc::Gpu),
            crate::device::Engine::Npu => out.push(Proc::Npu),
            crate::device::Engine::Dsp => out.push(Proc::Dsp),
            crate::device::Engine::Cpu => {}
        }
    }
    out
}

/// Per-task execution-configuration space `E_i`.
pub fn task_space(reg: &Registry, device: &Device, task: Task) -> Vec<Assignment> {
    let mut out = Vec::new();
    for variant in reg.variants_for_task(task) {
        for proc in proc_options(device) {
            if compatible(device, proc, variant.scheme) {
                out.push(Assignment { variant, proc });
            }
        }
    }
    out
}

/// Enumerate the decision space for a set of tasks, applying the
/// necessary-condition prefilter for multi-DNN products.
pub fn enumerate(
    reg: &Registry,
    device: &Device,
    tasks: &[Task],
    constraints: &[Constraint],
) -> Vec<Config> {
    let spaces: Vec<Vec<Assignment>> = tasks
        .iter()
        .map(|&t| task_space(reg, device, t))
        .collect();
    if tasks.len() == 1 {
        return spaces[0]
            .iter()
            .map(|&a| Config { assignments: vec![a] })
            .collect();
    }
    // Multi-DNN: prefilter each task space by solo-feasibility of latency
    // constraints (necessary condition), then take the product.
    let filtered: Vec<Vec<Assignment>> = spaces
        .iter()
        .enumerate()
        .map(|(t, space)| {
            space
                .iter()
                .copied()
                .filter(|a| solo_feasible(reg, device, *a, t, constraints))
                .collect()
        })
        .collect();
    let mut out = Vec::new();
    product(&filtered, &mut Vec::new(), &mut out);
    out
}

fn product(spaces: &[Vec<Assignment>], acc: &mut Vec<Assignment>, out: &mut Vec<Config>) {
    if acc.len() == spaces.len() {
        out.push(Config { assignments: acc.clone() });
        return;
    }
    for &a in &spaces[acc.len()] {
        acc.push(a);
        product(spaces, acc, out);
        acc.pop();
    }
}

/// Necessary condition: an assignment whose *solo* mean latency already
/// violates a per-task latency bound can never satisfy it under
/// contention (contention multiplies latency by >= 1).
fn solo_feasible(
    reg: &Registry,
    device: &Device,
    a: Assignment,
    task_idx: usize,
    constraints: &[Constraint],
) -> bool {
    let entry = &reg.models[a.variant.model];
    let perf = device.perf(a.proc.engine());
    let mean = perf.latency_ms(
        a.variant.flops(reg) * entry.batch as f64,
        a.proc,
        a.variant.scheme,
        entry.family,
    );
    for c in constraints {
        if c.metric == Metric::Latency
            && (c.task.is_none() || c.task == Some(task_idx))
        {
            // optimistic value per statistic: solo mean (max/std only grow)
            let optimistic = match c.stat {
                Statistic::Std => 0.0,
                _ => mean,
            };
            if optimistic > c.bound {
                return false;
            }
        }
    }
    true
}

/// Construct a full [`Problem`].
#[allow(clippy::too_many_arguments)]
pub fn build_problem(
    name: &str,
    tasks: Vec<Task>,
    device: Device,
    reg: Registry,
    objectives: Vec<super::Objective>,
    constraints: Vec<Constraint>,
    profile_seed: u64,
) -> Problem {
    let space = enumerate(&reg, &device, &tasks, &constraints);
    let cache = crate::profiler::profile_space(&reg, &device, &space, profile_seed);
    Problem {
        name: name.to_string(),
        tasks,
        device,
        registry: reg,
        objectives,
        constraints,
        space,
        cache,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::zoo::Scheme;

    #[test]
    fn uc1_space_size_s20() {
        // S20: 8 CPU configs + GPU + NPU. UC1 has 34 variants; GPU takes
        // fp32/fp16/fx8, NPU takes fp16/fx8/ffx8.
        let reg = Registry::paper();
        let dev = profiles::galaxy_s20();
        let space = task_space(&reg, &dev, Task::ImageCls);
        let cpu_only: usize = 34 * 8;
        assert!(space.len() > cpu_only, "space {} should include GPU/NPU", space.len());
        // every assignment is scheme-compatible
        for a in &space {
            assert!(compatible(&dev, a.proc, a.variant.scheme));
        }
    }

    #[test]
    fn a71_exposes_dsp_options() {
        let reg = Registry::paper();
        let dev = profiles::galaxy_a71();
        let space = task_space(&reg, &dev, Task::SceneCls);
        assert!(space.iter().any(|a| a.proc == Proc::Dsp
            && a.variant.scheme == Scheme::Ffx8));
        assert!(!space.iter().any(|a| a.proc == Proc::Dsp
            && a.variant.scheme != Scheme::Ffx8));
    }

    #[test]
    fn multi_product_dims() {
        let reg = Registry::paper();
        let dev = profiles::galaxy_s20();
        let cfgs = enumerate(&reg, &dev, &[Task::SceneCls, Task::AudioCls], &[]);
        let s1 = task_space(&reg, &dev, Task::SceneCls).len();
        let s2 = task_space(&reg, &dev, Task::AudioCls).len();
        assert_eq!(cfgs.len(), s1 * s2);
        assert!(cfgs.iter().all(|c| c.assignments.len() == 2));
    }

    #[test]
    fn prefilter_shrinks_uc4() {
        let reg = Registry::paper();
        let dev = profiles::galaxy_a71();
        let tasks = vec![Task::FaceGender, Task::FaceAge, Task::FaceEth];
        let tight = [Constraint {
            metric: Metric::Latency,
            stat: Statistic::Max,
            task: None,
            bound: 10.0,
        }];
        let with = enumerate(&reg, &dev, &tasks, &tight);
        let without_sz: usize = tasks
            .iter()
            .map(|&t| task_space(&reg, &dev, t).len())
            .product();
        assert!(with.len() < without_sz, "{} !< {}", with.len(), without_sz);
        assert!(!with.is_empty());
    }

    #[test]
    fn engine_set_and_colocation() {
        let reg = Registry::paper();
        let i = reg.find("GenderNet-MNV2").unwrap();
        let v = Variant { model: i, scheme: Scheme::Ffx8 };
        let cpu = Proc::Cpu { threads: 4, xnnpack: true };
        let cfg = Config {
            assignments: vec![
                Assignment { variant: v, proc: cpu },
                Assignment { variant: v, proc: cpu },
                Assignment { variant: v, proc: Proc::Npu },
            ],
        };
        assert_eq!(cfg.engine_set().len(), 2);
        assert_eq!(cfg.co_located(0), 1);
        assert_eq!(cfg.co_located(2), 0);
    }

    #[test]
    fn storage_dedups_shared_variants() {
        let reg = Registry::paper();
        let i = reg.find("GenderNet-MNV2").unwrap();
        let v = Variant { model: i, scheme: Scheme::Ffx8 };
        let cfg = Config {
            assignments: vec![
                Assignment { variant: v, proc: Proc::Npu },
                Assignment { variant: v, proc: Proc::Cpu { threads: 1, xnnpack: false } },
            ],
        };
        assert!((cfg.storage_bytes(&reg) - v.size_bytes(&reg)).abs() < 1.0);
    }
}
