//! RASS — the Runtime-Aware Sorting and Search solver (paper §4.3).
//!
//! RASS solves a device-specific MOO problem **once**, producing:
//!
//! * up to three per-engine-mapping designs `d_0..d_{T-1}` (the best
//!   solution of each of the top-T distinct model-to-processor mapping
//!   sets, T ≤ 3), enabling processor switching when an engine overloads;
//! * the memory-efficient design `d_m = argmin MF(x)`;
//! * the lightest-workload design `d_w = argmin W(x)`;
//! * `d_wm`, the better memory/workload balance of `d_m`/`d_w` by
//!   normalised-sum cost, for the processors-and-memory-troubled state;
//! * a total, state-indexed **switching policy** whose rules depend only
//!   on the environment booleans `(c_ce.., c_m)` — never on the currently
//!   deployed design — so the Runtime Manager switches in O(1).

use std::time::Instant;

use crate::device::Engine;

use super::optimality::ObjectiveStats;
use super::space::Config;
use super::{Design, Problem, Solution};

/// Maximum number of engine-mapping sets retained (paper: T <= 3).
pub const MAX_MAPPING_SETS: usize = 3;

/// Environment state the Runtime Manager indexes the policy with.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct EnvState {
    /// Troubled engines (overload/overheat), as a bitmask over
    /// [`Engine::index`].
    pub troubled: u8,
    /// Faulted engines: the supervised serving path observed repeated
    /// execution failures on the engine's route. Distinct signal from
    /// `troubled` (it comes from the coordinator, not the device
    /// monitor) but routed identically by the policy — a faulted engine
    /// must be avoided exactly like an overloaded one.
    pub faulted: u8,
    /// Memory pressure (`c_m`).
    pub memory: bool,
}

impl EnvState {
    pub fn calm() -> EnvState {
        EnvState { troubled: 0, faulted: 0, memory: false }
    }

    pub fn with_engine(mut self, e: Engine) -> EnvState {
        self.troubled |= 1 << e.index();
        self
    }

    pub fn with_faulted(mut self, e: Engine) -> EnvState {
        self.faulted |= 1 << e.index();
        self
    }

    pub fn with_memory(mut self) -> EnvState {
        self.memory = true;
        self
    }

    pub fn is_troubled(&self, e: Engine) -> bool {
        self.troubled & (1 << e.index()) != 0
    }

    pub fn is_faulted(&self, e: Engine) -> bool {
        self.faulted & (1 << e.index()) != 0
    }

    /// Engines the policy must route away from: troubled or faulted.
    pub fn bad_mask(&self) -> u8 {
        self.troubled | self.faulted
    }

    pub fn is_bad(&self, e: Engine) -> bool {
        self.bad_mask() & (1 << e.index()) != 0
    }

    /// No signal of any kind is raised.
    pub fn is_calm(&self) -> bool {
        self.bad_mask() == 0 && !self.memory
    }
}

/// The rule-based switching policy: a total map from environment state to
/// design index (paper §4.3.4). Materialised over every state of the
/// device's engines so lookups are branchless at runtime.
#[derive(Debug, Clone)]
pub struct SwitchingPolicy {
    /// Engines the device exposes (defines the state space).
    pub engines: Vec<Engine>,
    /// `rules[state_code] = design index`; state code packs the troubled
    /// bitmask (device-engine order) and the memory bit.
    rules: Vec<usize>,
}

impl SwitchingPolicy {
    /// A degenerate policy mapping **every** environment state to one
    /// design. Used by tests and benches that need a fixed task→engine
    /// mapping with no adaptive switching (e.g. measuring pure execution
    /// parallelism across two pinned engines).
    pub fn pinned(engines: Vec<Engine>, design: usize) -> SwitchingPolicy {
        let n_states = 1usize << (engines.len() + 1);
        SwitchingPolicy { engines, rules: vec![design; n_states] }
    }

    /// A policy from an explicit rule table: `rules[state_code]` is the
    /// design for that environment state, where the code packs the
    /// troubled/faulted bitmask in `engines` order plus the memory bit
    /// (so `rules.len()` must be `2^(engines.len() + 1)`). Lets tests
    /// and benches hand-author small fallback tables (e.g. "CPU bad →
    /// design 1") without running the solver.
    pub fn from_rules(engines: Vec<Engine>, rules: Vec<usize>) -> SwitchingPolicy {
        let n_states = 1usize << (engines.len() + 1);
        assert_eq!(
            rules.len(),
            n_states,
            "rule table must cover every environment state"
        );
        SwitchingPolicy { engines, rules }
    }

    fn state_code(&self, s: EnvState) -> usize {
        let mut code = 0usize;
        for (i, e) in self.engines.iter().enumerate() {
            // faulted folds into the troubled bit: both mean "route away
            // from this engine", so the policy table needs no extra states.
            if s.is_bad(*e) {
                code |= 1 << i;
            }
        }
        if s.memory {
            code |= 1 << self.engines.len();
        }
        code
    }

    /// O(1) design lookup for an environment state.
    pub fn design_for(&self, s: EnvState) -> usize {
        self.rules[self.state_code(s)]
    }

    pub fn n_states(&self) -> usize {
        self.rules.len()
    }

    /// Iterate (state, design-index) pairs, for policy dumps (Tables 7/8).
    pub fn iter_states(&self) -> impl Iterator<Item = (EnvState, usize)> + '_ {
        (0..self.rules.len()).map(move |code| {
            let mut s = EnvState::calm();
            for (i, e) in self.engines.iter().enumerate() {
                if code & (1 << i) != 0 {
                    s = s.with_engine(*e);
                }
            }
            if code & (1 << self.engines.len()) != 0 {
                s = s.with_memory();
            }
            (s, self.rules[code])
        })
    }
}

/// Solve a problem with RASS. Implements Algorithm 1 lines 9–12:
/// constrain, compute optimality, sort, search for the design set and
/// derive the switching policy.
pub fn solve(problem: &Problem) -> Solution {
    let t0 = Instant::now();

    // X' = {x | g_j(x) <= 0 ∀j} — apply constraints. The whole space is
    // evaluated in one parallel, memoised pass (`eval::evaluate_space`);
    // each configuration's metrics are reused for the objective vectors
    // and the d_m/d_w searches below (see EXPERIMENTS.md §Perf).
    let mut feasible: Vec<Config> = Vec::new();
    let mut vectors: Vec<Vec<f64>> = Vec::new();
    let mut mfs: Vec<f64> = Vec::new();
    let mut ws: Vec<f64> = Vec::new();
    let all_metrics = super::eval::evaluate_space(problem);
    for (x, m) in problem.space.iter().zip(all_metrics.iter()) {
        if !problem.feasible_metrics(m) {
            continue;
        }
        vectors.push(problem.objective_vector_of(m));
        mfs.push(m.total_mf_bytes());
        ws.push(m.total_flops());
        feasible.push(x.clone());
    }
    assert!(
        !feasible.is_empty(),
        "no feasible configuration for problem {}",
        problem.name
    );

    // CalculateOptimality + Sort.
    let stats = ObjectiveStats::from_vectors(problem, &vectors);
    let mut order: Vec<usize> = (0..feasible.len()).collect();
    let opts: Vec<f64> = vectors.iter().map(|v| stats.optimality(v)).collect();
    order.sort_by(|&a, &b| opts[b].partial_cmp(&opts[a]).unwrap());

    // Search: group the sorted space by model-to-processor mapping set
    // (the engine set the configuration occupies), keep the top-T sets.
    let mut sets: Vec<(Vec<Engine>, Vec<usize>)> = Vec::new();
    for &i in &order {
        let es = feasible[i].engine_set();
        match sets.iter_mut().find(|(k, _)| *k == es) {
            Some((_, v)) => v.push(i),
            None => sets.push((es, vec![i])),
        }
    }
    sets.truncate(MAX_MAPPING_SETS);
    let _t = sets.len();

    // d_i = best of each set (sets are already in descending set-best
    // optimality order because `order` is sorted).
    let mut designs: Vec<Design> = Vec::new();
    let roles_of = |cfg_idx: usize, role: &'static str, designs: &mut Vec<Design>| -> usize {
        if let Some(pos) = designs
            .iter()
            .position(|d| d.config == feasible[cfg_idx])
        {
            designs[pos].roles.push(role);
            pos
        } else {
            designs.push(Design {
                config: feasible[cfg_idx].clone(),
                optimality: opts[cfg_idx],
                roles: vec![role],
            });
            designs.len() - 1
        }
    };

    static DI_NAMES: [&str; 3] = ["d0", "d1", "d2"];
    let mut d_engine: Vec<usize> = Vec::new(); // design index per mapping set
    for (i, (_, members)) in sets.iter().enumerate() {
        d_engine.push(roles_of(members[0], DI_NAMES[i], &mut designs));
    }

    // The union of the retained subspaces X_0..X_{T-1}.
    let union: Vec<usize> = sets.iter().flat_map(|(_, m)| m.iter().copied()).collect();

    // d_m = argmin MF, d_w = argmin W over the union (memoized metrics).
    let mf = |i: usize| mfs[i];
    let w = |i: usize| ws[i];
    let i_m = *union
        .iter()
        .min_by(|&&a, &&b| mf(a).partial_cmp(&mf(b)).unwrap())
        .unwrap();
    let i_w = *union
        .iter()
        .min_by(|&&a, &&b| w(a).partial_cmp(&w(b)).unwrap())
        .unwrap();
    let d_m = roles_of(i_m, "dm", &mut designs);
    let d_w = roles_of(i_w, "dw", &mut designs);

    // d_wm: normalised-sum cost C(MF, W) between d_m and d_w.
    let (mf_m, w_m) = (mf(i_m), w(i_m));
    let (mf_w, w_w) = (mf(i_w), w(i_w));
    let nmf = mf_m.max(mf_w).max(1e-24);
    let nw = w_m.max(w_w).max(1e-24);
    let cost_m = mf_m / nmf + w_m / nw;
    let cost_w = mf_w / nmf + w_w / nw;
    let d_wm = if cost_w < cost_m { d_w } else { d_m };
    designs[d_wm].roles.push("dwm");

    let policy = build_policy(problem, &feasible, &designs, &sets, &d_engine, d_m, d_w, d_wm);

    crate::log_debug!(
        "rass: {} solved in {:.1} ms — {} feasible / {} space, {} designs, {} policy states",
        problem.name,
        t0.elapsed().as_secs_f64() * 1000.0,
        feasible.len(),
        problem.space.len(),
        designs.len(),
        policy.n_states()
    );

    Solution {
        designs,
        policy,
        feasible_count: feasible.len(),
        solve_time: t0.elapsed(),
    }
}

/// Construct the total switching policy.
///
/// Rule template (matches Tables 7 and 8):
/// * no trouble                → `d_0`
/// * memory only               → `d_m`
/// * engines S troubled, no mem → first `d_i` whose engine set avoids S;
///   if every mapping set intersects S → `d_w`
/// * engines S + memory        → first design among {d_m, d_i...}
///   avoiding S, preferring memory-light ones; if none → `d_wm`
#[allow(clippy::too_many_arguments)]
fn build_policy(
    problem: &Problem,
    feasible: &[Config],
    designs: &[Design],
    sets: &[(Vec<Engine>, Vec<usize>)],
    d_engine: &[usize],
    d_m: usize,
    d_w: usize,
    d_wm: usize,
) -> SwitchingPolicy {
    let _ = feasible;
    let engines = problem.device.engines.clone();
    let n_states = 1usize << (engines.len() + 1);
    let mut rules = vec![0usize; n_states];
    let policy_shell = SwitchingPolicy { engines: engines.clone(), rules: Vec::new() };

    let avoids = |design: usize, s: EnvState| -> bool {
        designs[design]
            .config
            .engine_set()
            .iter()
            .all(|e| !s.is_troubled(*e))
    };

    for code in 0..n_states {
        // decode
        let mut s = EnvState::calm();
        for (i, e) in engines.iter().enumerate() {
            if code & (1 << i) != 0 {
                s = s.with_engine(*e);
            }
        }
        if code & (1 << engines.len()) != 0 {
            s = s.with_memory();
        }

        let pick = if s.troubled == 0 && !s.memory {
            d_engine[0] // d_0
        } else if s.troubled == 0 && s.memory {
            d_m
        } else if !s.memory {
            // processor trouble: migrate to the best mapping set that
            // avoids every troubled engine (CP/CB), else shed workload (CM).
            d_engine
                .iter()
                .copied()
                .find(|&d| avoids(d, s))
                .unwrap_or(d_w)
        } else {
            // both processor and memory trouble: memory-efficient design if
            // it dodges the troubled engines, else the balanced d_wm.
            if avoids(d_m, s) {
                d_m
            } else if let Some(d) = d_engine.iter().copied().find(|&d| avoids(d, s) && d != d_engine[0]) {
                d
            } else {
                d_wm
            }
        };
        rules[code] = pick;
    }

    let _ = (sets, policy_shell);
    SwitchingPolicy { engines, rules }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;
    use crate::device::profiles;
    use crate::zoo::Registry;

    fn uc1_s20() -> (Problem, Solution) {
        let p = config::use_case("uc1", &Registry::paper(), &profiles::galaxy_s20())
            .unwrap();
        let s = solve(&p);
        (p, s)
    }

    #[test]
    fn at_most_five_designs() {
        let (_, s) = uc1_s20();
        assert!(s.designs.len() <= 5, "{} designs", s.designs.len());
        assert!(!s.designs.is_empty());
    }

    #[test]
    fn d0_is_max_optimality() {
        let (_, s) = uc1_s20();
        let d0 = s.designs.iter().find(|d| d.roles.contains(&"d0")).unwrap();
        for d in &s.designs {
            assert!(d0.optimality >= d.optimality - 1e-9);
        }
    }

    #[test]
    fn designs_are_feasible() {
        let (p, s) = uc1_s20();
        for d in &s.designs {
            assert!(p.feasible(&d.config), "{}", d.describe(&p));
        }
    }

    #[test]
    fn dm_minimises_memory_dw_minimises_workload() {
        let (p, s) = uc1_s20();
        let dm = s.designs.iter().find(|d| d.roles.contains(&"dm")).unwrap();
        let dw = s.designs.iter().find(|d| d.roles.contains(&"dw")).unwrap();
        let mf_m = p.metrics(&dm.config).total_mf_bytes();
        let w_w = p.metrics(&dw.config).total_flops();
        for d in &s.designs {
            assert!(p.metrics(&d.config).total_mf_bytes() >= mf_m - 1.0);
            assert!(p.metrics(&d.config).total_flops() >= w_w - 1.0);
        }
    }

    #[test]
    fn policy_total_and_state_only() {
        let (p, s) = uc1_s20();
        let n_e = p.device.engines.len();
        assert_eq!(s.policy.n_states(), 1 << (n_e + 1));
        for (_, d) in s.policy.iter_states() {
            assert!(d < s.designs.len());
        }
    }

    #[test]
    fn faulted_engine_routes_like_troubled() {
        // the serving-path fault signal must trigger the same degraded
        // design the overload signal does — one policy, two signal sources.
        let (_, s) = uc1_s20();
        for e in s.policy.engines.clone() {
            assert_eq!(
                s.policy.design_for(EnvState::calm().with_faulted(e)),
                s.policy.design_for(EnvState::calm().with_engine(e)),
            );
            assert_eq!(
                s.policy.design_for(EnvState::calm().with_faulted(e).with_memory()),
                s.policy.design_for(EnvState::calm().with_engine(e).with_memory()),
            );
        }
        // a faulted state is not calm and compares unequal to calm, so the
        // RM sees the flip and the flip back.
        let f = EnvState::calm().with_faulted(s.policy.engines[0]);
        assert!(!f.is_calm());
        assert_ne!(f, EnvState::calm());
    }

    #[test]
    fn calm_state_runs_d0_memory_state_runs_dm() {
        let (_, s) = uc1_s20();
        let d0 = s.policy.design_for(EnvState::calm());
        assert!(s.designs[d0].roles.contains(&"d0"));
        let dm = s.policy.design_for(EnvState::calm().with_memory());
        assert!(s.designs[dm].roles.contains(&"dm"));
    }

    #[test]
    fn troubled_engine_avoided_when_possible() {
        let (_, s) = uc1_s20();
        for (state, didx) in s.policy.iter_states() {
            if state.memory {
                continue;
            }
            let d = &s.designs[didx];
            let avoidable = s.designs.iter().any(|alt| {
                alt.config.engine_set().iter().all(|e| !state.is_troubled(*e))
            });
            if avoidable && !d.roles.contains(&"dw") {
                for e in d.config.engine_set() {
                    assert!(
                        !state.is_troubled(e),
                        "state {state:?} routed to design on troubled {e:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn multi_dnn_solves_uc3() {
        let p = config::use_case("uc3", &Registry::paper(), &profiles::galaxy_a71())
            .unwrap();
        let s = solve(&p);
        assert!(!s.designs.is_empty());
        assert!(s.designs.len() <= 5);
        // every design assigns both tasks
        for d in &s.designs {
            assert_eq!(d.config.assignments.len(), 2);
        }
    }

    #[test]
    fn solve_is_deterministic() {
        let p1 = config::use_case("uc1", &Registry::paper(), &profiles::pixel7()).unwrap();
        let s1 = solve(&p1);
        let s2 = solve(&p1);
        assert_eq!(s1.designs.len(), s2.designs.len());
        for (a, b) in s1.designs.iter().zip(&s2.designs) {
            assert_eq!(a.config, b.config);
        }
    }
}
