//! NSGA-II reference solver (§4.3 mentions evolutionary MOO solvers as
//! the conventional approach RASS replaces). Used by the ablation bench
//! to verify that RASS's `d_0` lands on (or next to) the evolutionary
//! Pareto front at a fraction of the cost, and to quantify the re-solve
//! cost an evolutionary solver would pay on every runtime event.

use crate::util::Rng;

use super::pareto::{crowding, non_dominated_sort};
use super::space::Config;
use super::Problem;

pub struct Nsga2Params {
    pub population: usize,
    pub generations: usize,
    pub mutation_rate: f64,
    pub seed: u64,
}

impl Default for Nsga2Params {
    fn default() -> Self {
        Nsga2Params { population: 64, generations: 40, mutation_rate: 0.15, seed: 7 }
    }
}

/// Genome: an index into the per-task assignment lists.
type Genome = Vec<usize>;

/// Run NSGA-II over the constrained space; returns the final Pareto front
/// as configurations.
pub fn solve(problem: &Problem, params: &Nsga2Params) -> Vec<Config> {
    let feasible: Vec<&Config> =
        problem.space.iter().filter(|x| problem.feasible(x)).collect();
    if feasible.is_empty() {
        return Vec::new();
    }
    // per-task gene pools from the feasible set
    let n_tasks = problem.tasks.len();
    let mut pools: Vec<Vec<super::space::Assignment>> = vec![Vec::new(); n_tasks];
    for cfg in &feasible {
        for (t, a) in cfg.assignments.iter().enumerate() {
            if !pools[t].contains(a) {
                pools[t].push(*a);
            }
        }
    }
    let mut rng = Rng::new(params.seed);
    let decode = |g: &Genome| Config {
        assignments: g.iter().enumerate().map(|(t, &i)| pools[t][i]).collect(),
    };
    let higher: Vec<bool> =
        problem.objectives.iter().map(|o| o.metric.higher_is_better()).collect();

    // init population
    let mut pop: Vec<Genome> = (0..params.population)
        .map(|_| (0..n_tasks).map(|t| rng.below(pools[t].len())).collect())
        .collect();

    for _ in 0..params.generations {
        // offspring by tournament + uniform crossover + mutation
        let vectors: Vec<Vec<f64>> = pop
            .iter()
            .map(|g| penalised_vector(problem, &decode(g), &higher))
            .collect();
        let ranks = non_dominated_sort(&vectors, &higher);
        let mut offspring: Vec<Genome> = Vec::with_capacity(pop.len());
        while offspring.len() < pop.len() {
            let a = tournament(&mut rng, &ranks);
            let b = tournament(&mut rng, &ranks);
            let mut child: Genome = (0..n_tasks)
                .map(|t| if rng.chance(0.5) { pop[a][t] } else { pop[b][t] })
                .collect();
            for (t, gene) in child.iter_mut().enumerate() {
                if rng.chance(params.mutation_rate) {
                    *gene = rng.below(pools[t].len());
                }
            }
            offspring.push(child);
        }
        // environmental selection over parents + offspring
        pop.extend(offspring);
        let vectors: Vec<Vec<f64>> = pop
            .iter()
            .map(|g| penalised_vector(problem, &decode(g), &higher))
            .collect();
        let ranks = non_dominated_sort(&vectors, &higher);
        let mut order: Vec<usize> = (0..pop.len()).collect();
        // sort by (rank, -crowding)
        let mut crowd = vec![0.0f64; pop.len()];
        let max_rank = ranks.iter().max().copied().unwrap_or(0);
        for r in 0..=max_rank {
            let members: Vec<usize> =
                (0..pop.len()).filter(|&i| ranks[i] == r).collect();
            let c = crowding(&vectors, &members);
            for (k, &i) in members.iter().enumerate() {
                crowd[i] = c[k];
            }
        }
        order.sort_by(|&a, &b| {
            ranks[a]
                .cmp(&ranks[b])
                .then(crowd[b].partial_cmp(&crowd[a]).unwrap())
        });
        order.truncate(params.population);
        pop = order.into_iter().map(|i| pop[i].clone()).collect();
    }

    // final front, deduplicated
    let vectors: Vec<Vec<f64>> = pop
        .iter()
        .map(|g| penalised_vector(problem, &decode(g), &higher))
        .collect();
    let ranks = non_dominated_sort(&vectors, &higher);
    let mut out: Vec<Config> = Vec::new();
    for (i, g) in pop.iter().enumerate() {
        if ranks[i] == 0 {
            let cfg = decode(g);
            if problem.feasible(&cfg) && !out.contains(&cfg) {
                out.push(cfg);
            }
        }
    }
    out
}

fn tournament(rng: &mut Rng, ranks: &[usize]) -> usize {
    let a = rng.below(ranks.len());
    let b = rng.below(ranks.len());
    if ranks[a] <= ranks[b] { a } else { b }
}

/// Objective vector with a death penalty on constraint violations so the
/// GA steers back into the feasible region.
fn penalised_vector(problem: &Problem, cfg: &Config, higher: &[bool]) -> Vec<f64> {
    let mut v = problem.objective_vector(cfg);
    let m = problem.metrics(cfg);
    let violated = problem.constraints.iter().any(|c| !c.satisfied(&m));
    if violated {
        for (i, x) in v.iter_mut().enumerate() {
            *x = if higher[i] { f64::MIN / 2.0 } else { f64::MAX / 2.0 };
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;
    use crate::device::profiles;
    use crate::zoo::Registry;

    #[test]
    fn front_is_feasible_and_nondominated() {
        let p = config::use_case("uc1", &Registry::paper(), &profiles::pixel7()).unwrap();
        let front = solve(&p, &Nsga2Params { population: 32, generations: 10, ..Default::default() });
        assert!(!front.is_empty());
        let higher: Vec<bool> =
            p.objectives.iter().map(|o| o.metric.higher_is_better()).collect();
        let vectors: Vec<Vec<f64>> =
            front.iter().map(|c| p.objective_vector(c)).collect();
        for (i, vi) in vectors.iter().enumerate() {
            for (j, vj) in vectors.iter().enumerate() {
                if i != j {
                    assert!(!super::super::pareto::dominates(vj, vi, &higher));
                }
            }
        }
        for c in &front {
            assert!(p.feasible(c));
        }
    }

    #[test]
    fn rass_d0_not_dominated_by_ga_front() {
        let p = config::use_case("uc1", &Registry::paper(), &profiles::galaxy_s20())
            .unwrap();
        let d0 = super::super::rass::solve(&p).designs[0].config.clone();
        let front = solve(&p, &Nsga2Params { population: 48, generations: 20, ..Default::default() });
        let higher: Vec<bool> =
            p.objectives.iter().map(|o| o.metric.higher_is_better()).collect();
        let v0 = p.objective_vector(&d0);
        let dominated = front
            .iter()
            .map(|c| p.objective_vector(c))
            .filter(|v| super::super::pareto::dominates(v, &v0, &higher))
            .count();
        // d_0 balances objectives rather than sitting at an extreme; it
        // must be on or adjacent to the front (dominated by at most a
        // couple of points, never deep inside the dominated region).
        assert!(dominated <= 2, "d0 dominated by {dominated} front points");
    }
}
