//! Unified error taxonomy for the coordinator/runtime layers.
//!
//! Before this module, the serving stack leaked `String` payloads
//! across thread boundaries (`Feedback::Ready`) and classified
//! failures by substring-matching `anyhow` chains. [`CarinError`]
//! gives each failure class a variant so supervision code can branch
//! on *kind* (a watchdog timeout retries differently from a bad
//! artifact) and reports can count `timed_out` separately from
//! `failed` without string sniffing.
//!
//! The coordinator layers keep `anyhow::Result` at their public
//! surface; a `CarinError` travels inside the chain and is recovered
//! with [`CarinError::find_in`], so intermediate `context()` calls
//! never erase the classification.

use std::fmt;

/// Classified failure in the serving/runtime stack.
#[derive(Debug, Clone, PartialEq)]
pub enum CarinError {
    /// Artifact problems: missing manifest entry, bad dtype/shape,
    /// load/compile failure.
    Artifact(String),
    /// Executor-side failure during inference (transient or hard).
    Engine(String),
    /// A supervised call exceeded its watchdog deadline; the hung
    /// executor thread was abandoned.
    Timeout {
        /// Model stem the call was routed to.
        stem: String,
        /// Deadline that fired, in milliseconds.
        deadline_ms: f64,
    },
    /// A request payload does not match the route's expected sample
    /// length; the request is counted `failed`, never panics the loop.
    ShapeMismatch {
        /// Sample length the batcher was built for.
        expected: usize,
        /// Length of the offending payload.
        got: usize,
    },
    /// Invalid configuration (policy, solution, CLI flags).
    Config(String),
    /// Filesystem / IO failure.
    Io(String),
}

impl CarinError {
    /// True if this is a watchdog [`CarinError::Timeout`].
    pub fn is_timeout(&self) -> bool {
        matches!(self, CarinError::Timeout { .. })
    }

    /// Short machine-readable kind name (stable; used in telemetry).
    pub fn kind(&self) -> &'static str {
        match self {
            CarinError::Artifact(_) => "artifact",
            CarinError::Engine(_) => "engine",
            CarinError::Timeout { .. } => "timeout",
            CarinError::ShapeMismatch { .. } => "shape",
            CarinError::Config(_) => "config",
            CarinError::Io(_) => "io",
        }
    }

    /// Recover the typed error from anywhere in an `anyhow` chain.
    ///
    /// Supervision code wraps engine errors with `context()` while
    /// retrying; this walks the chain so the original classification
    /// survives the decoration.
    pub fn find_in(err: &anyhow::Error) -> Option<&CarinError> {
        err.chain().find_map(|c| c.downcast_ref::<CarinError>())
    }
}

impl fmt::Display for CarinError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CarinError::Artifact(m) => write!(f, "artifact error: {m}"),
            CarinError::Engine(m) => write!(f, "engine error: {m}"),
            CarinError::Timeout { stem, deadline_ms } => {
                write!(f, "inference timed out: {stem} exceeded {deadline_ms:.1} ms deadline")
            }
            CarinError::ShapeMismatch { expected, got } => {
                write!(f, "shape mismatch: expected sample length {expected}, got {got}")
            }
            CarinError::Config(m) => write!(f, "config error: {m}"),
            CarinError::Io(m) => write!(f, "io error: {m}"),
        }
    }
}

impl std::error::Error for CarinError {}

impl From<std::io::Error> for CarinError {
    fn from(e: std::io::Error) -> Self {
        CarinError::Io(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use anyhow::Context;

    #[test]
    fn display_names_the_kind() {
        let e = CarinError::Timeout { stem: "scene_fx8".into(), deadline_ms: 12.5 };
        let s = e.to_string();
        assert!(s.contains("timed out") && s.contains("scene_fx8"), "{s}");
        assert_eq!(e.kind(), "timeout");
        assert!(e.is_timeout());
        assert!(!CarinError::Engine("x".into()).is_timeout());
    }

    #[test]
    fn survives_anyhow_context_chain() {
        let base = CarinError::Timeout { stem: "audio_fp32".into(), deadline_ms: 3.0 };
        let err = anyhow::Error::new(base.clone())
            .context("attempt 2 failed")
            .context("supervised call");
        let found = CarinError::find_in(&err).expect("typed error lost in chain");
        assert_eq!(*found, base);
    }

    #[test]
    fn io_errors_convert() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: CarinError = io.into();
        assert_eq!(e.kind(), "io");
        assert!(e.to_string().contains("gone"));
    }
}
