//! Use-case definitions (paper §6.2): SLO specifications for UC1–UC4,
//! plus a small text-based spec parser so custom applications can be
//! launched from the CLI without recompiling.

use std::time::Duration;

use crate::device::{Device, Engine, Proc};
use crate::moo::rass::SwitchingPolicy;
use crate::moo::space::{build_problem, Assignment};
use crate::moo::{Config, Constraint, Design, Metric, Objective, Problem, Solution, Statistic};
use crate::zoo::registry::Task;
use crate::zoo::{Registry, Scheme, Variant};

/// Deterministic profiling seed derived from the device (so reproductions
/// are stable but devices differ).
fn profile_seed(device: &Device) -> u64 {
    let mut h: u64 = 0xCA71_1234_5678_9ABC;
    for b in device.name.bytes() {
        h = h.wrapping_mul(0x100000001B3).wrapping_add(b as u64);
    }
    h
}

/// Build one of the paper's four use cases for a device.
///
/// * `uc1` — real-time image classification: max A, max TP
///   s.t. max L <= 41.67 ms (>= 24 FPS).
/// * `uc2` — text classification: min avg L, min S, max A
///   s.t. MF <= 90 MB.
/// * `uc3` — scene recognition (2 DNNs in parallel): min avg L_i,
///   min std L_i, max A_i s.t. avg L_i <= 100 ms, std L_i <= 10 ms.
/// * `uc4` — facial-attribute prediction (3 DNNs, batch 4): min avg L_i,
///   std L_i, S_i, MF_i, max A_i s.t. max L_i <= 10 ms.
pub fn use_case(name: &str, reg: &Registry, device: &Device) -> Option<Problem> {
    let seed = profile_seed(device);
    let p = match name.to_ascii_lowercase().as_str() {
        "uc1" => build_problem(
            "uc1",
            vec![Task::ImageCls],
            device.clone(),
            reg.clone(),
            vec![
                Objective::new(Metric::Accuracy),
                Objective::new(Metric::Throughput),
            ],
            vec![Constraint {
                metric: Metric::Latency,
                stat: Statistic::Max,
                task: None,
                bound: 41.67,
            }],
            seed,
        ),
        "uc2" => build_problem(
            "uc2",
            vec![Task::TextCls],
            device.clone(),
            reg.clone(),
            vec![
                Objective::new(Metric::Latency).stat(Statistic::Avg),
                Objective::new(Metric::Size),
                Objective::new(Metric::Accuracy),
            ],
            vec![Constraint {
                metric: Metric::MemFootprint,
                stat: Statistic::Avg,
                task: None,
                bound: 90e6,
            }],
            seed,
        ),
        "uc3" => {
            let mut objectives = Vec::new();
            let mut constraints = Vec::new();
            for i in 0..2 {
                objectives.push(Objective::new(Metric::Latency).stat(Statistic::Avg).task(i));
                objectives.push(Objective::new(Metric::Latency).stat(Statistic::Std).task(i));
                objectives.push(Objective::new(Metric::Accuracy).task(i));
                constraints.push(Constraint {
                    metric: Metric::Latency,
                    stat: Statistic::Avg,
                    task: Some(i),
                    bound: 100.0,
                });
                constraints.push(Constraint {
                    metric: Metric::Latency,
                    stat: Statistic::Std,
                    task: Some(i),
                    bound: 10.0,
                });
            }
            build_problem(
                "uc3",
                vec![Task::SceneCls, Task::AudioCls],
                device.clone(),
                reg.clone(),
                objectives,
                constraints,
                seed,
            )
        }
        "uc4" => {
            let tasks = vec![Task::FaceGender, Task::FaceAge, Task::FaceEth];
            let mut objectives = Vec::new();
            for i in 0..tasks.len() {
                objectives.push(Objective::new(Metric::Latency).stat(Statistic::Avg).task(i));
                objectives.push(Objective::new(Metric::Latency).stat(Statistic::Std).task(i));
                objectives.push(Objective::new(Metric::Size).task(i));
                objectives.push(Objective::new(Metric::MemFootprint).task(i));
                objectives.push(Objective::new(Metric::Accuracy).task(i));
            }
            let constraints = vec![Constraint {
                metric: Metric::Latency,
                stat: Statistic::Max,
                task: None, // every task
                bound: 10.0,
            }];
            build_problem(
                "uc4",
                tasks,
                device.clone(),
                reg.clone(),
                objectives,
                constraints,
                seed,
            )
        }
        _ => return None,
    };
    Some(p)
}

pub const USE_CASES: [&str; 4] = ["uc1", "uc2", "uc3", "uc4"];

/// A fixed single-design UC3-style solution: scene recognition pinned to
/// the CPU and audio classification pinned to the GPU, with a switching
/// policy that never leaves design 0.
///
/// Deterministic two-engine placement for the pooled-coordinator tests
/// and benches, where RASS's device-dependent choice (which may co-locate
/// both tasks on one processor) would make engine-parallelism assertions
/// meaningless.
pub fn pinned_uc3_solution(reg: &Registry) -> Solution {
    let scene = reg
        .models
        .iter()
        .position(|m| m.task == Task::SceneCls)
        .expect("registry has a scene model");
    let audio = reg
        .models
        .iter()
        .position(|m| m.task == Task::AudioCls)
        .expect("registry has an audio model");
    let config = Config {
        assignments: vec![
            Assignment {
                variant: Variant { model: scene, scheme: Scheme::Fx8 },
                proc: Proc::Cpu { threads: 4, xnnpack: true },
            },
            // YAMNet has no fixed-point accuracy entry, so the audio
            // route stays fp32
            Assignment {
                variant: Variant { model: audio, scheme: Scheme::Fp32 },
                proc: Proc::Gpu,
            },
        ],
    };
    Solution {
        designs: vec![Design { config, optimality: 1.0, roles: vec!["d0"] }],
        policy: SwitchingPolicy::pinned(vec![Engine::Cpu, Engine::Gpu], 0),
        feasible_count: 1,
        solve_time: Duration::ZERO,
    }
}

/// The [`pinned_uc3_solution`] placement plus a hand-authored fallback:
/// design 0 keeps scene on the CPU and audio on the GPU; design 1 moves
/// both tasks to the GPU. The switching policy routes every state where
/// the CPU is troubled or faulted to design 1 and everything else to
/// design 0, so supervision tests can fault the CPU route and assert a
/// real design switch (and the recovery back) without running the
/// solver.
pub fn pinned_uc3_fallback_solution(reg: &Registry) -> Solution {
    let base = pinned_uc3_solution(reg);
    let scene = base.designs[0].config.assignments[0].variant.model;
    let audio = base.designs[0].config.assignments[1].variant.model;
    let all_gpu = Config {
        assignments: vec![
            // the GPU route runs fp32: the scene model's fixed-point
            // scheme is a CPU/XNNPACK placement in the zoo
            Assignment {
                variant: Variant { model: scene, scheme: Scheme::Fp32 },
                proc: Proc::Gpu,
            },
            Assignment {
                variant: Variant { model: audio, scheme: Scheme::Fp32 },
                proc: Proc::Gpu,
            },
        ],
    };
    let engines = vec![Engine::Cpu, Engine::Gpu];
    // state code: bit 0 = CPU bad, bit 1 = GPU bad, bit 2 = memory
    let n_states = 1usize << (engines.len() + 1);
    let rules = (0..n_states).map(|code| usize::from(code & 1 != 0)).collect();
    Solution {
        designs: vec![
            base.designs.into_iter().next().expect("pinned solution has d0"),
            Design { config: all_gpu, optimality: 0.8, roles: vec!["cpu-fallback"] },
        ],
        policy: SwitchingPolicy::from_rules(engines, rules),
        feasible_count: 2,
        solve_time: Duration::ZERO,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;

    #[test]
    fn all_use_cases_build_on_all_devices() {
        let reg = Registry::paper();
        for d in profiles::all() {
            for uc in USE_CASES {
                let p = use_case(uc, &reg, &d)
                    .unwrap_or_else(|| panic!("{uc} on {}", d.name));
                assert!(!p.space.is_empty(), "{uc} on {} has empty space", d.name);
                assert!(!p.objectives.is_empty());
            }
        }
    }

    #[test]
    fn pinned_uc3_solution_spans_two_engines() {
        let reg = Registry::paper();
        let sol = pinned_uc3_solution(&reg);
        assert_eq!(sol.designs.len(), 1);
        let a = &sol.designs[0].config.assignments;
        assert_eq!(a.len(), 2);
        assert_ne!(a[0].proc.engine(), a[1].proc.engine());
        assert_eq!(sol.policy.engines, vec![Engine::Cpu, Engine::Gpu]);
        // the policy is genuinely pinned: every environment state maps
        // to design 0
        for troubled in 0u8..4 {
            for faulted in 0u8..4 {
                for memory in [false, true] {
                    let s = crate::moo::rass::EnvState { troubled, faulted, memory };
                    assert_eq!(sol.policy.design_for(s), 0);
                }
            }
        }
    }

    #[test]
    fn pinned_uc3_fallback_routes_cpu_bad_states_to_design_1() {
        let reg = Registry::paper();
        let sol = pinned_uc3_fallback_solution(&reg);
        assert_eq!(sol.designs.len(), 2);
        use crate::moo::rass::EnvState;
        assert_eq!(sol.policy.design_for(EnvState::calm()), 0);
        assert_eq!(
            sol.policy.design_for(EnvState::calm().with_engine(Engine::Cpu)),
            1,
            "troubled CPU must fall back"
        );
        assert_eq!(
            sol.policy.design_for(EnvState { troubled: 0, faulted: 1, memory: false }),
            1,
            "faulted CPU folds into the same fallback"
        );
        assert_eq!(sol.policy.design_for(EnvState::calm().with_engine(Engine::Gpu)), 0);
        assert!(
            sol.designs[1]
                .config
                .assignments
                .iter()
                .all(|a| a.proc.engine() == Engine::Gpu),
            "the fallback design must avoid the CPU entirely"
        );
    }

    #[test]
    fn unknown_use_case_is_none() {
        let reg = Registry::paper();
        let d = profiles::pixel7();
        assert!(use_case("uc9", &reg, &d).is_none());
    }

    #[test]
    fn uc1_objective_directions() {
        let reg = Registry::paper();
        let p = use_case("uc1", &reg, &profiles::pixel7()).unwrap();
        assert!(p.objectives.iter().all(|o| o.metric.higher_is_better()));
        assert_eq!(p.constraints.len(), 1);
    }

    #[test]
    fn uc4_has_15_objectives() {
        let reg = Registry::paper();
        let p = use_case("uc4", &reg, &profiles::galaxy_s20()).unwrap();
        assert_eq!(p.objectives.len(), 15);
        assert_eq!(p.tasks.len(), 3);
    }
}
