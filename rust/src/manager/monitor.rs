//! Environment monitor: samples the device state into the boolean vector
//! `(c_ce.., c_m)` the switching policy is indexed with (paper §4.3.4:
//! "several system parameters ... need to be continuously monitored").
//!
//! A hysteresis window debounces the signals so transient spikes do not
//! cause design thrash. Besides the simulator-sourced overload/memory
//! signals, the monitor accepts an externally reported **fault** signal
//! per engine (raised by the serving coordinator's supervised execution
//! when a route fails repeatedly, cleared when health probes succeed);
//! it is debounced with the same hold window and surfaces as
//! [`EnvState::faulted`].

use crate::device::{Engine, Simulator};
use crate::moo::rass::EnvState;

/// Debouncing monitor over the simulator's raw signals plus the
/// coordinator's fault reports.
#[derive(Debug, Clone)]
pub struct Monitor {
    engines: Vec<Engine>,
    /// Consecutive samples a signal must hold before it flips.
    hold: usize,
    counts_on: Vec<usize>,
    counts_off: Vec<usize>,
    mem_on: usize,
    mem_off: usize,
    fault_on: Vec<usize>,
    fault_off: Vec<usize>,
    /// Raw externally-reported fault bits (pre-debounce), over
    /// [`Engine::index`].
    fault_raw: u8,
    state: EnvState,
}

impl Monitor {
    pub fn new(engines: Vec<Engine>, hold: usize) -> Self {
        let n = engines.len();
        Monitor {
            engines,
            hold,
            counts_on: vec![0; n],
            counts_off: vec![0; n],
            mem_on: 0,
            mem_off: 0,
            fault_on: vec![0; n],
            fault_off: vec![0; n],
            fault_raw: 0,
            state: EnvState::calm(),
        }
    }

    pub fn state(&self) -> EnvState {
        self.state
    }

    /// Raise or clear the raw fault signal for an engine. The debounced
    /// [`EnvState::faulted`] bit follows after `hold` consecutive
    /// [`Monitor::tick`]/[`Monitor::sample`] observations.
    pub fn report_fault(&mut self, e: Engine, faulted: bool) {
        if faulted {
            self.fault_raw |= 1 << e.index();
        } else {
            self.fault_raw &= !(1 << e.index());
        }
    }

    /// Whether a raw (pre-debounce) fault is currently reported.
    pub fn fault_reported(&self, e: Engine) -> bool {
        self.fault_raw & (1 << e.index()) != 0
    }

    /// The raw (pre-debounce) fault bitmask over [`Engine::index`] —
    /// exported as a telemetry gauge so dashboards can see reported
    /// faults before the hysteresis window admits them.
    pub fn raw_fault_mask(&self) -> u8 {
        self.fault_raw
    }

    /// Debounce the externally-reported fault bits into `next`.
    fn debounce_faults(&mut self, mut next: EnvState) -> EnvState {
        for (i, &e) in self.engines.iter().enumerate() {
            let raw = self.fault_raw & (1 << e.index()) != 0;
            if raw {
                self.fault_on[i] += 1;
                self.fault_off[i] = 0;
                if self.fault_on[i] >= self.hold && !next.is_faulted(e) {
                    next = next.with_faulted(e);
                }
            } else {
                self.fault_off[i] += 1;
                self.fault_on[i] = 0;
                if self.fault_off[i] >= self.hold && next.is_faulted(e) {
                    next.faulted &= !(1 << e.index());
                }
            }
        }
        next
    }

    /// Advance only the fault signal — the serving loop has no device
    /// simulator in the loop, so overload/memory bits keep their last
    /// debounced value. Returns the (debounced) state.
    pub fn tick(&mut self) -> EnvState {
        let next = self.debounce_faults(self.state);
        self.state = next;
        next
    }

    /// Sample the simulator; returns the (debounced) state. Also advances
    /// the fault-signal debounce, so mixed sim+fault deployments need only
    /// one call per round.
    pub fn sample(&mut self, sim: &Simulator) -> EnvState {
        let mut next = self.state;
        for (i, &e) in self.engines.iter().enumerate() {
            let raw = sim.engine_troubled(e);
            if raw {
                self.counts_on[i] += 1;
                self.counts_off[i] = 0;
                if self.counts_on[i] >= self.hold && !next.is_troubled(e) {
                    next = next.with_engine(e);
                }
            } else {
                self.counts_off[i] += 1;
                self.counts_on[i] = 0;
                if self.counts_off[i] >= self.hold && next.is_troubled(e) {
                    next.troubled &= !(1 << e.index());
                }
            }
        }
        let raw_mem = sim.memory_pressured();
        if raw_mem {
            self.mem_on += 1;
            self.mem_off = 0;
            if self.mem_on >= self.hold {
                next.memory = true;
            }
        } else {
            self.mem_off += 1;
            self.mem_on = 0;
            if self.mem_off >= self.hold {
                next.memory = false;
            }
        }
        let next = self.debounce_faults(next);
        self.state = next;
        next
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;

    #[test]
    fn debounce_holds_transients() {
        let dev = profiles::galaxy_s20();
        let mut sim = Simulator::new(dev.clone(), 1);
        let mut mon = Monitor::new(dev.engines.clone(), 3);
        sim.set_external_load(Engine::Cpu, 0.9);
        // needs 3 consecutive samples to flip
        assert!(!mon.sample(&sim).is_troubled(Engine::Cpu));
        assert!(!mon.sample(&sim).is_troubled(Engine::Cpu));
        assert!(mon.sample(&sim).is_troubled(Engine::Cpu));
        // single calm sample does not clear it
        sim.set_external_load(Engine::Cpu, 0.0);
        assert!(mon.sample(&sim).is_troubled(Engine::Cpu));
        mon.sample(&sim);
        assert!(!mon.sample(&sim).is_troubled(Engine::Cpu));
    }

    #[test]
    fn memory_signal_tracks_pressure() {
        let dev = profiles::galaxy_s20();
        let mut sim = Simulator::new(dev.clone(), 1);
        let mut mon = Monitor::new(dev.engines.clone(), 1);
        assert!(!mon.sample(&sim).memory);
        sim.set_background_ram(sim.device.ram_bytes() * 0.62);
        assert!(mon.sample(&sim).memory);
    }

    #[test]
    fn fault_signal_debounces_like_overload() {
        let dev = profiles::galaxy_s20();
        let mut mon = Monitor::new(dev.engines.clone(), 2);
        mon.report_fault(Engine::Cpu, true);
        assert!(!mon.tick().is_faulted(Engine::Cpu));
        assert!(mon.tick().is_faulted(Engine::Cpu));
        // recovery also needs `hold` consecutive calm observations
        mon.report_fault(Engine::Cpu, false);
        assert!(mon.tick().is_faulted(Engine::Cpu));
        assert!(!mon.tick().is_faulted(Engine::Cpu));
        assert!(mon.state().is_calm());
    }

    #[test]
    fn raw_fault_mask_tracks_reports() {
        let dev = profiles::galaxy_s20();
        let mut mon = Monitor::new(dev.engines.clone(), 2);
        assert_eq!(mon.raw_fault_mask(), 0);
        mon.report_fault(Engine::Gpu, true);
        mon.report_fault(Engine::Cpu, true);
        assert_eq!(
            mon.raw_fault_mask(),
            (1 << Engine::Gpu.index()) | (1 << Engine::Cpu.index())
        );
        mon.report_fault(Engine::Gpu, false);
        assert_eq!(mon.raw_fault_mask(), 1 << Engine::Cpu.index());
    }

    #[test]
    fn flapping_fault_never_flips_state() {
        let dev = profiles::galaxy_s20();
        let mut mon = Monitor::new(dev.engines.clone(), 3);
        for i in 0..100 {
            mon.report_fault(Engine::Gpu, i % 2 == 0);
            assert!(!mon.tick().is_faulted(Engine::Gpu), "flap leaked at {i}");
        }
    }

    #[test]
    fn fault_and_sim_signals_compose() {
        let dev = profiles::galaxy_s20();
        let mut sim = Simulator::new(dev.clone(), 1);
        let mut mon = Monitor::new(dev.engines.clone(), 1);
        sim.set_external_load(Engine::Cpu, 0.9);
        mon.report_fault(Engine::Gpu, true);
        let s = mon.sample(&sim);
        assert!(s.is_troubled(Engine::Cpu));
        assert!(s.is_faulted(Engine::Gpu));
        assert!(s.is_bad(Engine::Cpu) && s.is_bad(Engine::Gpu));
    }
}
