//! Environment monitor: samples the device state into the boolean vector
//! `(c_ce.., c_m)` the switching policy is indexed with (paper §4.3.4:
//! "several system parameters ... need to be continuously monitored").
//!
//! A hysteresis window debounces the signals so transient spikes do not
//! cause design thrash.

use crate::device::{Engine, Simulator};
use crate::moo::rass::EnvState;

/// Debouncing monitor over the simulator's raw signals.
#[derive(Debug, Clone)]
pub struct Monitor {
    engines: Vec<Engine>,
    /// Consecutive samples a signal must hold before it flips.
    hold: usize,
    counts_on: Vec<usize>,
    counts_off: Vec<usize>,
    mem_on: usize,
    mem_off: usize,
    state: EnvState,
}

impl Monitor {
    pub fn new(engines: Vec<Engine>, hold: usize) -> Self {
        let n = engines.len();
        Monitor {
            engines,
            hold,
            counts_on: vec![0; n],
            counts_off: vec![0; n],
            mem_on: 0,
            mem_off: 0,
            state: EnvState::calm(),
        }
    }

    pub fn state(&self) -> EnvState {
        self.state
    }

    /// Sample the simulator; returns the (debounced) state.
    pub fn sample(&mut self, sim: &Simulator) -> EnvState {
        let mut next = self.state;
        for (i, &e) in self.engines.iter().enumerate() {
            let raw = sim.engine_troubled(e);
            if raw {
                self.counts_on[i] += 1;
                self.counts_off[i] = 0;
                if self.counts_on[i] >= self.hold && !next.is_troubled(e) {
                    next = next.with_engine(e);
                }
            } else {
                self.counts_off[i] += 1;
                self.counts_on[i] = 0;
                if self.counts_off[i] >= self.hold && next.is_troubled(e) {
                    next.troubled &= !(1 << e.index());
                }
            }
        }
        let raw_mem = sim.memory_pressured();
        if raw_mem {
            self.mem_on += 1;
            self.mem_off = 0;
            if self.mem_on >= self.hold {
                next.memory = true;
            }
        } else {
            self.mem_off += 1;
            self.mem_on = 0;
            if self.mem_off >= self.hold {
                next.memory = false;
            }
        }
        self.state = next;
        next
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;

    #[test]
    fn debounce_holds_transients() {
        let dev = profiles::galaxy_s20();
        let mut sim = Simulator::new(dev.clone(), 1);
        let mut mon = Monitor::new(dev.engines.clone(), 3);
        sim.set_external_load(Engine::Cpu, 0.9);
        // needs 3 consecutive samples to flip
        assert!(!mon.sample(&sim).is_troubled(Engine::Cpu));
        assert!(!mon.sample(&sim).is_troubled(Engine::Cpu));
        assert!(mon.sample(&sim).is_troubled(Engine::Cpu));
        // single calm sample does not clear it
        sim.set_external_load(Engine::Cpu, 0.0);
        assert!(mon.sample(&sim).is_troubled(Engine::Cpu));
        mon.sample(&sim);
        assert!(!mon.sample(&sim).is_troubled(Engine::Cpu));
    }

    #[test]
    fn memory_signal_tracks_pressure() {
        let dev = profiles::galaxy_s20();
        let mut sim = Simulator::new(dev.clone(), 1);
        let mut mon = Monitor::new(dev.engines.clone(), 1);
        assert!(!mon.sample(&sim).memory);
        sim.set_background_ram(sim.device.ram_bytes() * 0.62);
        assert!(mon.sample(&sim).memory);
    }
}
