//! The Runtime Manager proper: consumes monitor states, looks the new
//! design up in the RASS switching policy (O(1)) and records switch
//! latencies — the paper's headline adaptation-overhead claim (§7.2.3:
//! OODIn re-solves in 0.5–34 ms; CARIn switches "instantaneously").

use std::collections::BTreeMap;
use std::time::Instant;

use crate::moo::rass::EnvState;
use crate::moo::Solution;
use crate::util::json::Json;

/// One recorded design switch: the audit-trail record of a policy
/// decision (the environment state seen, the `bad_mask` it indexed the
/// switching table with, the designs involved, and the lookup latency).
#[derive(Debug, Clone)]
pub struct SwitchRecord {
    pub sim_time_s: f64,
    pub from: usize,
    pub to: usize,
    pub state: EnvState,
    /// `state.bad_mask()` at decision time (troubled | faulted bits).
    pub bad_mask: u8,
    /// Wall-clock the decision took (policy lookup only).
    pub decision_ns: u128,
}

impl SwitchRecord {
    /// The record as a JSON object (audit-trail export).
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("sim_time_s".to_string(), Json::Num(self.sim_time_s));
        m.insert("from".to_string(), Json::Num(self.from as f64));
        m.insert("to".to_string(), Json::Num(self.to as f64));
        m.insert("troubled".to_string(), Json::Num(self.state.troubled as f64));
        m.insert("faulted".to_string(), Json::Num(self.state.faulted as f64));
        m.insert("memory".to_string(), Json::Bool(self.state.memory));
        m.insert("bad_mask".to_string(), Json::Num(self.bad_mask as f64));
        m.insert("decision_ns".to_string(), Json::Num(self.decision_ns as f64));
        Json::Obj(m)
    }
}

/// Runtime Manager: the online half of CARIn (Algorithm 1 lines 13–18).
pub struct RuntimeManager {
    pub solution: Solution,
    current: usize,
    last_state: EnvState,
    pub switches: Vec<SwitchRecord>,
}

impl RuntimeManager {
    pub fn new(solution: Solution) -> Self {
        let current = solution.policy.design_for(EnvState::calm());
        RuntimeManager {
            solution,
            current,
            last_state: EnvState::calm(),
            switches: Vec::new(),
        }
    }

    pub fn current_design(&self) -> usize {
        self.current
    }

    /// Feed a monitor state; returns `Some(new design)` when the RM
    /// switched. The decision is a pure policy lookup — its latency is
    /// recorded per switch for the Table-9 comparison.
    pub fn observe(&mut self, state: EnvState, sim_time_s: f64) -> Option<usize> {
        if state == self.last_state {
            return None;
        }
        let t0 = Instant::now();
        let next = self.solution.policy.design_for(state);
        let decision_ns = t0.elapsed().as_nanos();
        self.last_state = state;
        if next != self.current {
            self.switches.push(SwitchRecord {
                sim_time_s,
                from: self.current,
                to: next,
                state,
                bad_mask: state.bad_mask(),
                decision_ns,
            });
            self.current = next;
            return Some(next);
        }
        None
    }

    /// Switches made while some signal (overload, fault, memory) was
    /// raised — the RM falling back to a degraded design.
    pub fn fallback_count(&self) -> usize {
        self.switches.iter().filter(|s| !s.state.is_calm()).count()
    }

    /// Switches made once every signal cleared — the RM recovering to
    /// the calm design.
    pub fn recovery_count(&self) -> usize {
        self.switches.iter().filter(|s| s.state.is_calm()).count()
    }

    /// The full switch audit trail as a JSON array (decision replay).
    pub fn audit_json(&self) -> Json {
        Json::Arr(self.switches.iter().map(|s| s.to_json()).collect())
    }

    /// Mean decision latency across recorded switches (ns).
    pub fn mean_decision_ns(&self) -> f64 {
        if self.switches.is_empty() {
            return 0.0;
        }
        self.switches.iter().map(|s| s.decision_ns as f64).sum::<f64>()
            / self.switches.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;
    use crate::device::profiles;
    use crate::device::Engine;
    use crate::moo::rass;
    use crate::zoo::Registry;

    fn rm() -> RuntimeManager {
        let p = config::use_case("uc1", &Registry::paper(), &profiles::galaxy_s20())
            .unwrap();
        RuntimeManager::new(rass::solve(&p))
    }

    #[test]
    fn starts_on_d0() {
        let m = rm();
        assert!(m.solution.designs[m.current_design()].roles.contains(&"d0"));
    }

    #[test]
    fn switches_on_state_change_only() {
        let mut m = rm();
        assert!(m.observe(EnvState::calm(), 0.0).is_none());
        let troubled = EnvState::calm().with_engine(Engine::Cpu);
        let d = m.observe(troubled, 1.0);
        assert!(d.is_some());
        // same state again: no new switch
        assert!(m.observe(troubled, 2.0).is_none());
        // recovery goes back to d0
        let back = m.observe(EnvState::calm(), 3.0).unwrap();
        assert!(m.solution.designs[back].roles.contains(&"d0"));
        assert_eq!(m.switches.len(), 2);
    }

    #[test]
    fn decision_is_sub_microsecond() {
        let mut m = rm();
        m.observe(EnvState::calm().with_engine(Engine::Cpu), 0.0);
        m.observe(EnvState::calm().with_memory(), 1.0);
        // policy lookups must be far below OODIn's 0.55 ms best case
        assert!(m.mean_decision_ns() < 100_000.0, "{} ns", m.mean_decision_ns());
    }

    #[test]
    fn faulted_state_falls_back_then_recovers() {
        let mut m = rm();
        // serving-path fault on the calm design's engine: degrade...
        let f = EnvState::calm().with_faulted(Engine::Cpu);
        let d = m.observe(f, 0.0);
        assert!(d.is_some(), "fault signal must trigger a fallback switch");
        // ...and recover once the probe path clears the signal.
        let back = m.observe(EnvState::calm(), 1.0).unwrap();
        assert!(m.solution.designs[back].roles.contains(&"d0"));
        assert_eq!(m.fallback_count(), 1);
        assert_eq!(m.recovery_count(), 1);
    }

    #[test]
    fn audit_trail_records_bad_mask_and_exports_json() {
        let mut m = rm();
        m.observe(EnvState::calm().with_faulted(Engine::Cpu), 0.5);
        m.observe(EnvState::calm(), 1.0);
        assert_eq!(m.switches.len(), 2);
        assert_eq!(m.switches[0].bad_mask, 1 << Engine::Cpu.index());
        assert_eq!(m.switches[1].bad_mask, 0);
        let audit = m.audit_json();
        let rows = match &audit {
            crate::util::json::Json::Arr(rows) => rows,
            other => panic!("expected array, got {other:?}"),
        };
        assert_eq!(rows.len(), 2);
        // the dump round-trips through the parser with fields intact
        let parsed =
            crate::util::json::Json::parse(&audit.dump()).expect("valid audit json");
        let first = match &parsed {
            crate::util::json::Json::Arr(rows) => &rows[0],
            other => panic!("expected array, got {other:?}"),
        };
        assert_eq!(first.get("bad_mask").unwrap().as_usize().unwrap(), 1);
        assert!(first.get("decision_ns").is_some());
        assert_eq!(first.get("memory"), Some(&crate::util::json::Json::Bool(false)));
    }

    #[test]
    fn memory_state_selects_dm() {
        let mut m = rm();
        let d = m.observe(EnvState::calm().with_memory(), 0.0).unwrap();
        assert!(m.solution.designs[d].roles.contains(&"dm"));
    }
}
