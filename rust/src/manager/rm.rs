//! The Runtime Manager proper: consumes monitor states, looks the new
//! design up in the RASS switching policy (O(1)) and records switch
//! latencies — the paper's headline adaptation-overhead claim (§7.2.3:
//! OODIn re-solves in 0.5–34 ms; CARIn switches "instantaneously").

use std::time::Instant;

use crate::moo::rass::EnvState;
use crate::moo::Solution;

/// One recorded design switch.
#[derive(Debug, Clone)]
pub struct SwitchRecord {
    pub sim_time_s: f64,
    pub from: usize,
    pub to: usize,
    pub state: EnvState,
    /// Wall-clock the decision took (policy lookup only).
    pub decision_ns: u128,
}

/// Runtime Manager: the online half of CARIn (Algorithm 1 lines 13–18).
pub struct RuntimeManager {
    pub solution: Solution,
    current: usize,
    last_state: EnvState,
    pub switches: Vec<SwitchRecord>,
}

impl RuntimeManager {
    pub fn new(solution: Solution) -> Self {
        let current = solution.policy.design_for(EnvState::calm());
        RuntimeManager {
            solution,
            current,
            last_state: EnvState::calm(),
            switches: Vec::new(),
        }
    }

    pub fn current_design(&self) -> usize {
        self.current
    }

    /// Feed a monitor state; returns `Some(new design)` when the RM
    /// switched. The decision is a pure policy lookup — its latency is
    /// recorded per switch for the Table-9 comparison.
    pub fn observe(&mut self, state: EnvState, sim_time_s: f64) -> Option<usize> {
        if state == self.last_state {
            return None;
        }
        let t0 = Instant::now();
        let next = self.solution.policy.design_for(state);
        let decision_ns = t0.elapsed().as_nanos();
        self.last_state = state;
        if next != self.current {
            self.switches.push(SwitchRecord {
                sim_time_s,
                from: self.current,
                to: next,
                state,
                decision_ns,
            });
            self.current = next;
            return Some(next);
        }
        None
    }

    /// Switches made while some signal (overload, fault, memory) was
    /// raised — the RM falling back to a degraded design.
    pub fn fallback_count(&self) -> usize {
        self.switches.iter().filter(|s| !s.state.is_calm()).count()
    }

    /// Switches made once every signal cleared — the RM recovering to
    /// the calm design.
    pub fn recovery_count(&self) -> usize {
        self.switches.iter().filter(|s| s.state.is_calm()).count()
    }

    /// Mean decision latency across recorded switches (ns).
    pub fn mean_decision_ns(&self) -> f64 {
        if self.switches.is_empty() {
            return 0.0;
        }
        self.switches.iter().map(|s| s.decision_ns as f64).sum::<f64>()
            / self.switches.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;
    use crate::device::profiles;
    use crate::device::Engine;
    use crate::moo::rass;
    use crate::zoo::Registry;

    fn rm() -> RuntimeManager {
        let p = config::use_case("uc1", &Registry::paper(), &profiles::galaxy_s20())
            .unwrap();
        RuntimeManager::new(rass::solve(&p))
    }

    #[test]
    fn starts_on_d0() {
        let m = rm();
        assert!(m.solution.designs[m.current_design()].roles.contains(&"d0"));
    }

    #[test]
    fn switches_on_state_change_only() {
        let mut m = rm();
        assert!(m.observe(EnvState::calm(), 0.0).is_none());
        let troubled = EnvState::calm().with_engine(Engine::Cpu);
        let d = m.observe(troubled, 1.0);
        assert!(d.is_some());
        // same state again: no new switch
        assert!(m.observe(troubled, 2.0).is_none());
        // recovery goes back to d0
        let back = m.observe(EnvState::calm(), 3.0).unwrap();
        assert!(m.solution.designs[back].roles.contains(&"d0"));
        assert_eq!(m.switches.len(), 2);
    }

    #[test]
    fn decision_is_sub_microsecond() {
        let mut m = rm();
        m.observe(EnvState::calm().with_engine(Engine::Cpu), 0.0);
        m.observe(EnvState::calm().with_memory(), 1.0);
        // policy lookups must be far below OODIn's 0.55 ms best case
        assert!(m.mean_decision_ns() < 100_000.0, "{} ns", m.mean_decision_ns());
    }

    #[test]
    fn faulted_state_falls_back_then_recovers() {
        let mut m = rm();
        // serving-path fault on the calm design's engine: degrade...
        let f = EnvState::calm().with_faulted(Engine::Cpu);
        let d = m.observe(f, 0.0);
        assert!(d.is_some(), "fault signal must trigger a fallback switch");
        // ...and recover once the probe path clears the signal.
        let back = m.observe(EnvState::calm(), 1.0).unwrap();
        assert!(m.solution.designs[back].roles.contains(&"d0"));
        assert_eq!(m.fallback_count(), 1);
        assert_eq!(m.recovery_count(), 1);
    }

    #[test]
    fn memory_state_selects_dm() {
        let mut m = rm();
        let d = m.observe(EnvState::calm().with_memory(), 0.0).unwrap();
        assert!(m.solution.designs[d].roles.contains(&"dm"));
    }
}
