//! The Runtime Manager (RM) and its monitoring loop (paper §3.2, §7.2):
//! watches the environment booleans `(c_ce.., c_m)` coming from the
//! device monitor and swaps execution plans through the RASS switching
//! policy — a constant-time table lookup, no re-solving.

pub mod events;
pub mod monitor;
pub mod rm;

pub use events::{Event, EventSchedule};
pub use monitor::Monitor;
pub use rm::{RuntimeManager, SwitchRecord};
