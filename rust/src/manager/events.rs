//! Runtime-challenge injection (paper §4.3.2): scripted schedules of
//! processor overload/overheat and RAM-pressure events, replayed against
//! the device simulator to exercise the Runtime Manager.

use crate::device::simulator::Governor;
use crate::device::{Engine, Simulator};

/// One environmental change.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Event {
    /// Background process pins `load` (0..1) of an engine.
    EngineLoad { engine: Engine, load: f64 },
    /// Force a die temperature (overheat / cool-down).
    Temperature { engine: Engine, temp_c: f64 },
    /// Background apps now hold `bytes` of RAM.
    BackgroundRam { bytes: f64 },
    /// The OS switched the DVFS governor (thermal policy, battery saver).
    Governor { governor: Governor },
}

impl Event {
    pub fn apply(&self, sim: &mut Simulator) {
        match *self {
            Event::EngineLoad { engine, load } => sim.set_external_load(engine, load),
            Event::Temperature { engine, temp_c } => sim.set_temperature(engine, temp_c),
            Event::BackgroundRam { bytes } => sim.set_background_ram(bytes),
            Event::Governor { governor } => sim.set_governor(governor),
        }
    }

    pub fn describe(&self) -> String {
        match *self {
            Event::EngineLoad { engine, load } => {
                format!("{} load -> {:.0}%", engine.name(), load * 100.0)
            }
            Event::Temperature { engine, temp_c } => {
                format!("{} temp -> {temp_c:.0}°C", engine.name())
            }
            Event::BackgroundRam { bytes } => {
                format!("background RAM -> {:.0} MB", bytes / 1e6)
            }
            Event::Governor { governor } => {
                format!("governor -> {}", governor.name())
            }
        }
    }
}

/// A time-ordered schedule of events (seconds on the simulated clock).
#[derive(Debug, Clone, Default)]
pub struct EventSchedule {
    items: Vec<(f64, Event)>,
}

impl EventSchedule {
    pub fn new(mut items: Vec<(f64, Event)>) -> Self {
        items.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        EventSchedule { items }
    }

    /// Pop and apply every event due at or before `now_s`. Returns the
    /// applied events.
    pub fn apply_due(&mut self, sim: &mut Simulator, now_s: f64) -> Vec<Event> {
        let mut applied = Vec::new();
        while let Some(&(t, e)) = self.items.first() {
            if t > now_s {
                break;
            }
            e.apply(sim);
            applied.push(e);
            self.items.remove(0);
        }
        applied
    }

    pub fn remaining(&self) -> usize {
        self.items.len()
    }

    /// The Figure-7 scenario (UC1 on S20): gradual CPU overload, then a
    /// memory squeeze, then recovery.
    pub fn figure7(ram_total: f64) -> EventSchedule {
        EventSchedule::new(vec![
            (5.0, Event::EngineLoad { engine: Engine::Cpu, load: 0.45 }),
            (8.0, Event::EngineLoad { engine: Engine::Cpu, load: 0.85 }),
            (14.0, Event::EngineLoad { engine: Engine::Cpu, load: 0.0 }),
            (16.0, Event::BackgroundRam { bytes: ram_total * 0.62 }),
            (24.0, Event::BackgroundRam { bytes: ram_total * 0.15 }),
        ])
    }

    /// The Figure-8 scenario (UC3 on A71): the fixed-function accelerator
    /// carrying the vision model overloads (audio capture pipelines also
    /// contend for it, §7.2.2), forcing a migration; a RAM squeeze then
    /// selects the memory-efficient design; both recover; the accelerator
    /// overloads again.
    pub fn figure8(ram_total: f64) -> EventSchedule {
        EventSchedule::new(vec![
            (4.0, Event::EngineLoad { engine: Engine::Npu, load: 0.9 }),
            (10.0, Event::BackgroundRam { bytes: ram_total * 0.60 }),
            (18.0, Event::BackgroundRam { bytes: ram_total * 0.15 }),
            (20.0, Event::EngineLoad { engine: Engine::Npu, load: 0.0 }),
            (28.0, Event::EngineLoad { engine: Engine::Npu, load: 0.9 }),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;

    #[test]
    fn schedule_applies_in_order() {
        let mut sim = Simulator::new(profiles::galaxy_a71(), 1);
        let mut sched = EventSchedule::new(vec![
            (2.0, Event::EngineLoad { engine: Engine::Cpu, load: 0.5 }),
            (1.0, Event::EngineLoad { engine: Engine::Gpu, load: 0.3 }),
        ]);
        assert!(sched.apply_due(&mut sim, 0.5).is_empty());
        let a = sched.apply_due(&mut sim, 1.5);
        assert_eq!(a.len(), 1);
        assert!(matches!(a[0], Event::EngineLoad { engine: Engine::Gpu, .. }));
        let b = sched.apply_due(&mut sim, 10.0);
        assert_eq!(b.len(), 1);
        assert_eq!(sched.remaining(), 0);
        assert!(sim.external_load(Engine::Cpu) > 0.4);
    }

    #[test]
    fn figure_scenarios_nonempty() {
        assert!(EventSchedule::figure7(6e9).remaining() >= 4);
        assert!(EventSchedule::figure8(6e9).remaining() >= 5);
    }
}
