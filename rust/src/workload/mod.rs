//! Workload generators for the four use cases: arrival processes that
//! feed the serving coordinator and the trace driver.

use std::sync::mpsc;
use std::time::Instant;

use crate::coordinator::serve::ServeRequest;
use crate::util::Rng;

/// Arrival process shapes.
#[derive(Debug, Clone, Copy)]
pub enum Arrival {
    /// Fixed-rate stream (UC1's 24 FPS camera).
    Periodic { hz: f64 },
    /// Poisson arrivals (UC2's text messages).
    Poisson { hz: f64 },
    /// Bursts of `burst` back-to-back requests (UC4's face crops per
    /// detected frame).
    Bursty { hz: f64, burst: usize },
}

/// A synthetic workload for one task.
#[derive(Debug, Clone, Copy)]
pub struct TaskWorkload {
    pub task: usize,
    pub arrival: Arrival,
    pub total: usize,
    /// Per-request completion budget (ms from submission), derived from
    /// the task's SLO. Requests that cannot finish inside it are shed by
    /// the coordinator. `None` disables shedding.
    pub deadline_ms: Option<f64>,
}

/// Generate the request timeline of a workload (offsets in seconds).
pub fn timeline(w: &TaskWorkload, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed ^ (w.task as u64) << 32);
    let mut out = Vec::with_capacity(w.total);
    let mut t = 0.0;
    match w.arrival {
        Arrival::Periodic { hz } => {
            for i in 0..w.total {
                out.push(i as f64 / hz);
            }
        }
        Arrival::Poisson { hz } => {
            for _ in 0..w.total {
                t += -rng.f64().max(1e-12).ln() / hz;
                out.push(t);
            }
        }
        Arrival::Bursty { hz, burst } => {
            let mut emitted = 0;
            while emitted < w.total {
                for _ in 0..burst.min(w.total - emitted) {
                    out.push(t);
                    emitted += 1;
                }
                t += 1.0 / hz;
            }
        }
    }
    out
}

/// Spawn producer threads feeding `tx` according to the workloads, in
/// real time (sleeps between arrivals). Returns the join handles.
pub fn spawn_producers(
    workloads: Vec<TaskWorkload>,
    tx: mpsc::Sender<ServeRequest>,
    seed: u64,
    time_scale: f64,
) -> Vec<std::thread::JoinHandle<()>> {
    workloads
        .into_iter()
        .map(|w| {
            let tx = tx.clone();
            std::thread::spawn(move || {
                let times = timeline(&w, seed);
                let start = Instant::now();
                for (i, &due) in times.iter().enumerate() {
                    let due = due * time_scale;
                    let elapsed = start.elapsed().as_secs_f64();
                    if due > elapsed {
                        std::thread::sleep(std::time::Duration::from_secs_f64(due - elapsed));
                    }
                    let now = Instant::now();
                    let _ = tx.send(ServeRequest {
                        task: w.task,
                        id: (w.task as u64) << 48 | i as u64,
                        submitted: now,
                        // absolute deadlines stay in real time even when
                        // arrivals are time-scaled: the SLO budget is a
                        // property of the request, not of the generator
                        deadline: w.deadline_ms.map(|d| {
                            now + std::time::Duration::from_secs_f64(d / 1000.0)
                        }),
                    });
                }
            })
        })
        .collect()
}

/// Canonical workloads per use case (arrival shapes from §6.2). The
/// per-request deadline budgets derive from each use case's latency SLO
/// (uc1: max L <= 41.67 ms, uc3: avg L <= 100 ms, uc4: max L <= 10 ms)
/// with generous headroom for queueing, so shedding only engages when a
/// request genuinely cannot make it; uc2 is throughput-bound (no
/// per-request deadline).
pub fn for_use_case(uc: &str, requests_per_task: usize) -> Vec<TaskWorkload> {
    match uc {
        "uc1" => vec![TaskWorkload {
            task: 0,
            arrival: Arrival::Periodic { hz: 24.0 },
            total: requests_per_task,
            deadline_ms: Some(4.0 * 41.67),
        }],
        "uc2" => vec![TaskWorkload {
            task: 0,
            arrival: Arrival::Poisson { hz: 10.0 },
            total: requests_per_task,
            deadline_ms: None,
        }],
        "uc3" => vec![
            TaskWorkload {
                task: 0,
                arrival: Arrival::Periodic { hz: 10.0 },
                total: requests_per_task,
                deadline_ms: Some(400.0),
            },
            TaskWorkload {
                task: 1,
                arrival: Arrival::Periodic { hz: 1.0 / 0.975 },
                total: requests_per_task,
                deadline_ms: Some(400.0),
            },
        ],
        "uc4" => (0..3)
            .map(|t| TaskWorkload {
                task: t,
                arrival: Arrival::Bursty { hz: 5.0, burst: 4 },
                total: requests_per_task,
                deadline_ms: Some(100.0),
            })
            .collect(),
        _ => Vec::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn periodic_timeline_spacing() {
        let w = TaskWorkload {
            task: 0,
            arrival: Arrival::Periodic { hz: 24.0 },
            total: 48,
            deadline_ms: None,
        };
        let t = timeline(&w, 1);
        assert_eq!(t.len(), 48);
        assert!((t[1] - t[0] - 1.0 / 24.0).abs() < 1e-9);
        assert!((t[47] - 47.0 / 24.0).abs() < 1e-9);
    }

    #[test]
    fn poisson_mean_rate_close() {
        let w = TaskWorkload {
            task: 0,
            arrival: Arrival::Poisson { hz: 100.0 },
            total: 5000,
            deadline_ms: None,
        };
        let t = timeline(&w, 2);
        let rate = t.len() as f64 / t.last().unwrap();
        assert!((rate - 100.0).abs() < 10.0, "rate {rate}");
    }

    #[test]
    fn bursts_are_coincident() {
        let w = TaskWorkload {
            task: 0,
            arrival: Arrival::Bursty { hz: 5.0, burst: 4 },
            total: 12,
            deadline_ms: None,
        };
        let t = timeline(&w, 3);
        assert_eq!(t.len(), 12);
        assert_eq!(t[0], t[3]);
        assert!(t[4] > t[3]);
    }

    #[test]
    fn use_case_task_counts() {
        assert_eq!(for_use_case("uc1", 10).len(), 1);
        assert_eq!(for_use_case("uc3", 10).len(), 2);
        assert_eq!(for_use_case("uc4", 10).len(), 3);
    }

    #[test]
    fn use_case_deadlines_follow_slos() {
        // latency-bound use cases carry a deadline budget; the
        // throughput-bound uc2 must never shed
        assert!(for_use_case("uc1", 1)[0].deadline_ms.is_some());
        assert!(for_use_case("uc2", 1)[0].deadline_ms.is_none());
        assert!(for_use_case("uc4", 1).iter().all(|w| w.deadline_ms == Some(100.0)));
    }

    #[test]
    fn timelines_monotone() {
        for uc in ["uc1", "uc2", "uc3", "uc4"] {
            for w in for_use_case(uc, 50) {
                let t = timeline(&w, 7);
                for i in 1..t.len() {
                    assert!(t[i] >= t[i - 1]);
                }
            }
        }
    }
}
