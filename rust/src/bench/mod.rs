//! Minimal criterion-style benchmarking harness (criterion is not in the
//! offline registry): warm-up, timed iterations, and a robust summary
//! printed in a stable, greppable format.

use std::time::{Duration, Instant};

use crate::util::Summary;

/// One benchmark's result.
#[derive(Debug)]
pub struct BenchResult {
    pub name: String,
    pub iterations: usize,
    /// Per-iteration wall-clock in ms.
    pub per_iter_ms: Summary,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "bench {:40} iters={:<5} mean={:>10.4} ms  p50={:>10.4} ms  p95={:>10.4} ms  min={:>10.4} ms",
            self.name,
            self.iterations,
            self.per_iter_ms.mean,
            self.per_iter_ms.percentile(50.0),
            self.per_iter_ms.percentile(95.0),
            self.per_iter_ms.min,
        );
    }
}

/// Benchmark runner with a time budget.
pub struct Bencher {
    pub warmup: Duration,
    pub budget: Duration,
    pub max_iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            budget: Duration::from_secs(2),
            max_iters: 2000,
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher {
            warmup: Duration::from_millis(50),
            budget: Duration::from_millis(500),
            max_iters: 200,
        }
    }

    /// Time `f` repeatedly; prevents the result from being optimised out
    /// via `std::hint::black_box`.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchResult {
        // warm-up
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::new();
        let b0 = Instant::now();
        while b0.elapsed() < self.budget && samples.len() < self.max_iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64() * 1000.0);
        }
        if samples.is_empty() {
            samples.push(f64::NAN);
        }
        let r = BenchResult {
            name: name.to_string(),
            iterations: samples.len(),
            per_iter_ms: Summary::of(&samples),
        };
        r.report();
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bencher { warmup: Duration::from_millis(1), budget: Duration::from_millis(20), max_iters: 50 };
        let r = b.run("noop", || 1 + 1);
        assert!(r.iterations > 0);
        assert!(r.per_iter_ms.mean >= 0.0);
    }

    #[test]
    fn respects_max_iters() {
        let b = Bencher { warmup: Duration::from_millis(1), budget: Duration::from_secs(5), max_iters: 10 };
        let r = b.run("capped", || ());
        assert!(r.iterations <= 10);
    }
}
