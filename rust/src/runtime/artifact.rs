//! Artifact manifest: metadata for every compiled (model, scheme) pair,
//! parsed from `artifacts/manifest.json` with the in-tree JSON parser.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// Tensor element types crossing the rust/JAX boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    I8,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            "int8" => Ok(DType::I8),
            other => Err(anyhow!("unsupported dtype {other}")),
        }
    }

    pub fn size_bytes(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::I8 => 1,
        }
    }
}

/// Interned handle for one artifact: the manifest index of a compiled
/// (model, scheme) variant, assigned once at coordinator build time by
/// `coordinator::router::RouteTable`.
///
/// The serving hot path passes these `Copy` ids instead of cloning stem
/// `String`s — routing, telemetry events, fault bookkeeping and the
/// watchdog channel all move a `u32`; display names are resolved back
/// through the route table only at export/report time.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ArtifactId(pub u32);

impl ArtifactId {
    /// Index into the manifest / route table this id was interned from.
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

impl std::fmt::Display for ArtifactId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "route#{}", self.0)
    }
}

/// Shape + dtype of one I/O tensor.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        let shape = j
            .get("shape")
            .and_then(|s| s.as_arr())
            .context("missing shape")?
            .iter()
            .map(|x| x.as_usize().context("bad dim"))
            .collect::<Result<Vec<_>>>()?;
        let dtype = DType::parse(
            j.get("dtype").and_then(|d| d.as_str()).context("missing dtype")?,
        )?;
        Ok(TensorSpec { shape, dtype })
    }
}

/// One manifest entry (one AOT-compiled model variant).
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    /// Artifact stem, e.g. `cnn_s_ffx8`.
    pub stem: String,
    pub hlo_path: PathBuf,
    pub weights_path: PathBuf,
    /// npz keys in graph-parameter order (after the input).
    pub weight_keys: Vec<String>,
    pub model: String,
    pub task: String,
    pub scheme: String,
    pub input: TensorSpec,
    pub outputs: Vec<TensorSpec>,
    pub params: usize,
    pub flops: f64,
    pub weight_bytes: usize,
    /// FFX8 input quantisation scale (int8 = round(f32 / scale)).
    pub input_scale: Option<f64>,
}

/// Load and validate `<dir>/manifest.json`.
pub fn load_manifest(dir: &Path) -> Result<Vec<ArtifactMeta>> {
    let text = std::fs::read_to_string(dir.join("manifest.json"))
        .with_context(|| format!("reading {}/manifest.json — run `make artifacts`", dir.display()))?;
    let root = Json::parse(&text).map_err(|e| anyhow!("{e}"))?;
    let mut out = Vec::new();
    for e in root.as_arr().context("manifest must be an array")? {
        let file = e.get("file").and_then(|f| f.as_str()).context("file")?;
        let stem = file.trim_end_matches(".hlo.txt").to_string();
        let weight_keys = e
            .get("weight_keys")
            .and_then(|k| k.as_arr())
            .context("weight_keys")?
            .iter()
            .map(|x| x.as_str().map(String::from).context("weight key"))
            .collect::<Result<Vec<_>>>()?;
        out.push(ArtifactMeta {
            hlo_path: dir.join(file),
            weights_path: dir.join(
                e.get("weights").and_then(|w| w.as_str()).context("weights")?,
            ),
            weight_keys,
            model: e.get("model").and_then(|m| m.as_str()).context("model")?.into(),
            task: e.get("task").and_then(|t| t.as_str()).context("task")?.into(),
            scheme: e.get("scheme").and_then(|s| s.as_str()).context("scheme")?.into(),
            input: TensorSpec::from_json(e.get("input").context("input")?)?,
            outputs: e
                .get("outputs")
                .and_then(|o| o.as_arr())
                .context("outputs")?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?,
            params: e.get("params").and_then(|p| p.as_usize()).context("params")?,
            flops: e.get("flops").and_then(|f| f.as_f64()).context("flops")?,
            weight_bytes: e
                .get("weight_bytes")
                .and_then(|w| w.as_usize())
                .context("weight_bytes")?,
            input_scale: e.get("input_scale").and_then(|s| s.as_f64()),
            stem,
        });
    }
    Ok(out)
}

/// Find the artifact for a (model, scheme) pair.
pub fn find<'a>(
    manifest: &'a [ArtifactMeta],
    model: &str,
    scheme: &str,
) -> Option<&'a ArtifactMeta> {
    manifest.iter().find(|m| m.model == model && m.scheme == scheme)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn manifest_loads_when_built() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = load_manifest(&dir).unwrap();
        assert!(!m.is_empty());
        for a in &m {
            assert!(a.hlo_path.exists(), "{}", a.hlo_path.display());
            assert!(a.weights_path.exists(), "{}", a.weights_path.display());
            assert!(!a.weight_keys.is_empty());
            assert!(a.input.numel() > 0);
        }
        // ffx8 artifacts carry an input scale and int8 I/O
        let ffx8 = find(&m, "cnn_s", "ffx8").expect("cnn_s ffx8 missing");
        assert_eq!(ffx8.input.dtype, DType::I8);
        assert_eq!(ffx8.outputs[0].dtype, DType::I8);
        assert!(ffx8.input_scale.unwrap() > 0.0);
    }

    #[test]
    fn dtype_parse_rejects_unknown() {
        assert!(DType::parse("float64").is_err());
        assert_eq!(DType::parse("int8").unwrap(), DType::I8);
    }
}
