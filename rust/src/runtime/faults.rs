//! Fault-injection harness for the serving path (the robustness
//! substrate behind CARIn's "responsiveness under adversity" claim).
//!
//! Every executor sits behind the [`Inference`] trait; the
//! [`FaultInjector`] decorator wraps any executor and injects **seeded,
//! deterministic** faults with per-model probabilities:
//!
//! * *transient errors* — an inference call fails, the next may succeed;
//! * *latency spikes* — the call succeeds but burns extra wall-clock;
//! * *load failures* — compiling/uploading a model fails;
//! * *outage windows* — a per-stem call-index interval during which every
//!   call fails (a hard engine outage, used to force fallback switches).
//!
//! [`StubEngine`] is a PJRT-free executor (zero logits, optional fixed
//! latency) so chaos tests and benches run without `make artifacts`;
//! [`synthetic_manifest`] fabricates the matching artifact metadata for
//! the whole model registry.

use std::collections::HashMap;
use std::fmt;
use std::time::Duration;

use anyhow::{anyhow, Result};

use super::artifact::{ArtifactMeta, DType, TensorSpec};
use super::engine::{InferenceEngine, Tensor};
use crate::util::Rng;
use crate::zoo::{Registry, Scheme};

/// The executor abstraction the serving coordinator supervises. The real
/// PJRT engine, the stub engine and the fault injector all implement it,
/// so supervision and injection compose with any backend.
pub trait Inference {
    /// Run one inference on a loaded model.
    fn infer(&mut self, stem: &str, input: &Tensor) -> Result<Tensor>;
    /// Compile an artifact and make it resident. Idempotent per stem.
    fn load(&mut self, meta: &ArtifactMeta) -> Result<()>;
    /// Drop a resident model.
    fn unload(&mut self, stem: &str);
    fn is_loaded(&self, stem: &str) -> bool;
    /// Number of resident models.
    fn loaded_count(&self) -> usize;
    /// Injection counters, if this executor (or a decorator in its stack)
    /// injects faults. Lets pooled workers — whose engines are consumed by
    /// their owning thread — report injector activity back to tests.
    fn fault_stats(&self) -> Option<FaultStats> {
        None
    }
}

impl Inference for InferenceEngine {
    fn infer(&mut self, stem: &str, input: &Tensor) -> Result<Tensor> {
        InferenceEngine::infer(self, stem, input)
    }

    fn load(&mut self, meta: &ArtifactMeta) -> Result<()> {
        InferenceEngine::load(self, meta)
    }

    fn unload(&mut self, stem: &str) {
        InferenceEngine::unload(self, stem)
    }

    fn is_loaded(&self, stem: &str) -> bool {
        InferenceEngine::is_loaded(self, stem)
    }

    fn loaded_count(&self) -> usize {
        self.loaded().len()
    }
}

/// What kind of fault was injected (error taxonomy for reports/tests).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// One-shot execution failure; retrying may succeed.
    Transient,
    /// Hard outage window: every call in the window fails.
    Outage,
    /// Model load/compile failure.
    Load,
}

impl FaultKind {
    pub fn name(self) -> &'static str {
        match self {
            FaultKind::Transient => "transient",
            FaultKind::Outage => "outage",
            FaultKind::Load => "load",
        }
    }
}

/// The error type injected faults surface as; supervised execution (and
/// tests) can `downcast_ref::<InjectedFault>()` to classify failures.
#[derive(Debug, Clone)]
pub struct InjectedFault {
    pub kind: FaultKind,
    pub stem: String,
    /// Per-stem call index at which the fault fired (1-based).
    pub call: u64,
}

impl fmt::Display for InjectedFault {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "injected {} fault on {} (call #{})",
            self.kind.name(),
            self.stem,
            self.call
        )
    }
}

impl std::error::Error for InjectedFault {}

/// Per-model fault probabilities and schedules. All fields default to
/// "no fault"; combine with the builder methods.
#[derive(Debug, Clone, Default)]
pub struct FaultSpec {
    /// Per-call probability of a transient execution error.
    pub transient_p: f64,
    /// Per-call probability of a latency spike.
    pub spike_p: f64,
    /// Injected extra latency per spike, ms.
    pub spike_ms: f64,
    /// Per-call probability that a `load()` fails.
    pub load_fail_p: f64,
    /// Inclusive per-stem call-index window `[from, to]` (1-based) during
    /// which every inference fails — a hard outage.
    pub outage: Option<(u64, u64)>,
}

impl FaultSpec {
    /// Only transient errors with probability `p`.
    pub fn transient(p: f64) -> FaultSpec {
        FaultSpec { transient_p: p, ..FaultSpec::default() }
    }

    /// Add latency spikes: probability `p`, `ms` extra wall-clock each.
    pub fn with_spikes(mut self, p: f64, ms: f64) -> FaultSpec {
        self.spike_p = p;
        self.spike_ms = ms;
        self
    }

    /// Add load failures with probability `p`.
    pub fn with_load_failures(mut self, p: f64) -> FaultSpec {
        self.load_fail_p = p;
        self
    }

    /// Add a hard outage over the inclusive call window `[from, to]`.
    pub fn with_outage(mut self, from: u64, to: u64) -> FaultSpec {
        self.outage = Some((from, to));
        self
    }
}

/// Running injection counters (what the harness actually did).
#[derive(Debug, Clone, Default)]
pub struct FaultStats {
    pub calls: u64,
    pub injected_errors: u64,
    pub injected_spikes: u64,
    pub failed_loads: u64,
}

impl FaultStats {
    /// Accumulate another executor's counters (per-worker stats reduce
    /// into one report-time total).
    pub fn absorb(&mut self, other: &FaultStats) {
        self.calls += other.calls;
        self.injected_errors += other.injected_errors;
        self.injected_spikes += other.injected_spikes;
        self.failed_loads += other.failed_loads;
    }
}

/// Deterministic fault-injecting decorator around any [`Inference`]
/// executor. Faults are drawn from a seeded [`Rng`], so a given seed and
/// call sequence replays the exact same fault schedule.
pub struct FaultInjector<E: Inference> {
    inner: E,
    rng: Rng,
    default_spec: FaultSpec,
    per_stem: HashMap<String, FaultSpec>,
    /// Per-stem inference call counts (1-based after increment).
    calls: HashMap<String, u64>,
    pub stats: FaultStats,
}

impl<E: Inference> FaultInjector<E> {
    pub fn new(inner: E, seed: u64) -> FaultInjector<E> {
        FaultInjector {
            inner,
            rng: Rng::new(seed ^ 0xFA17_FA17_FA17_FA17),
            default_spec: FaultSpec::default(),
            per_stem: HashMap::new(),
            calls: HashMap::new(),
            stats: FaultStats::default(),
        }
    }

    /// Fault spec applied to stems without a dedicated entry.
    pub fn set_default(&mut self, spec: FaultSpec) {
        self.default_spec = spec;
    }

    /// Fault spec for one model stem (overrides the default).
    pub fn set_for(&mut self, stem: &str, spec: FaultSpec) {
        self.per_stem.insert(stem.to_string(), spec);
    }

    pub fn inner(&self) -> &E {
        &self.inner
    }

    pub fn inner_mut(&mut self) -> &mut E {
        &mut self.inner
    }

    pub fn into_inner(self) -> E {
        self.inner
    }

    /// Inference calls observed for a stem so far.
    pub fn calls_for(&self, stem: &str) -> u64 {
        self.calls.get(stem).copied().unwrap_or(0)
    }

    fn spec_for(&self, stem: &str) -> FaultSpec {
        self.per_stem.get(stem).unwrap_or(&self.default_spec).clone()
    }
}

impl<E: Inference> Inference for FaultInjector<E> {
    fn infer(&mut self, stem: &str, input: &Tensor) -> Result<Tensor> {
        let call = {
            let c = self.calls.entry(stem.to_string()).or_insert(0);
            *c += 1;
            *c
        };
        self.stats.calls += 1;
        let spec = self.spec_for(stem);
        if let Some((from, to)) = spec.outage {
            if call >= from && call <= to {
                self.stats.injected_errors += 1;
                crate::log_trace!("inject outage fault on {stem} (call #{call})");
                return Err(InjectedFault {
                    kind: FaultKind::Outage,
                    stem: stem.to_string(),
                    call,
                }
                .into());
            }
        }
        if spec.transient_p > 0.0 && self.rng.chance(spec.transient_p) {
            self.stats.injected_errors += 1;
            crate::log_trace!("inject transient fault on {stem} (call #{call})");
            return Err(InjectedFault {
                kind: FaultKind::Transient,
                stem: stem.to_string(),
                call,
            }
            .into());
        }
        if spec.spike_p > 0.0 && self.rng.chance(spec.spike_p) {
            self.stats.injected_spikes += 1;
            std::thread::sleep(Duration::from_secs_f64(spec.spike_ms.max(0.0) / 1000.0));
        }
        self.inner.infer(stem, input)
    }

    fn load(&mut self, meta: &ArtifactMeta) -> Result<()> {
        let spec = self.spec_for(&meta.stem);
        if spec.load_fail_p > 0.0 && self.rng.chance(spec.load_fail_p) {
            self.stats.failed_loads += 1;
            return Err(InjectedFault {
                kind: FaultKind::Load,
                stem: meta.stem.clone(),
                call: self.calls_for(&meta.stem),
            }
            .into());
        }
        self.inner.load(meta)
    }

    fn unload(&mut self, stem: &str) {
        self.inner.unload(stem)
    }

    fn is_loaded(&self, stem: &str) -> bool {
        self.inner.is_loaded(stem)
    }

    fn loaded_count(&self) -> usize {
        self.inner.loaded_count()
    }

    fn fault_stats(&self) -> Option<FaultStats> {
        Some(self.stats.clone())
    }
}

/// PJRT-free executor: validates requests against the artifact metadata
/// and returns an all-zero logits tensor, optionally burning `exec_ms`
/// of wall-clock per call. Lets chaos tests, examples and benches run
/// the full coordinator stack without `make artifacts`.
#[derive(Debug, Default)]
pub struct StubEngine {
    models: HashMap<String, ArtifactMeta>,
    /// Simulated execution latency per call, ms (0 = instant).
    pub exec_ms: f64,
}

impl StubEngine {
    pub fn new() -> StubEngine {
        StubEngine { models: HashMap::new(), exec_ms: 0.0 }
    }

    pub fn with_latency(exec_ms: f64) -> StubEngine {
        StubEngine { models: HashMap::new(), exec_ms }
    }
}

impl Inference for StubEngine {
    fn infer(&mut self, stem: &str, input: &Tensor) -> Result<Tensor> {
        let meta = self
            .models
            .get(stem)
            .ok_or_else(|| anyhow!("model {stem} not loaded"))?;
        if input.dtype() != meta.input.dtype {
            return Err(anyhow!(
                "{stem}: input dtype {:?} != manifest {:?}",
                input.dtype(),
                meta.input.dtype
            ));
        }
        if input.len() != meta.input.numel() {
            return Err(anyhow!(
                "{stem}: input numel {} != manifest {}",
                input.len(),
                meta.input.numel()
            ));
        }
        let n = meta.outputs[0].numel();
        if self.exec_ms > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(self.exec_ms / 1000.0));
        }
        Ok(Tensor::F32(vec![0.0; n]))
    }

    fn load(&mut self, meta: &ArtifactMeta) -> Result<()> {
        self.models.entry(meta.stem.clone()).or_insert_with(|| meta.clone());
        Ok(())
    }

    fn unload(&mut self, stem: &str) {
        self.models.remove(stem);
    }

    fn is_loaded(&self, stem: &str) -> bool {
        self.models.contains_key(stem)
    }

    fn loaded_count(&self) -> usize {
        self.models.len()
    }
}

/// Fabricate an artifact manifest covering every (artifact, scheme) pair
/// of the registry, for [`StubEngine`]-backed runs. Shapes are small and
/// rank ≤ 2 (no batched rank-4 inputs) so payload generation stays cheap.
pub fn synthetic_manifest(reg: &Registry) -> Vec<ArtifactMeta> {
    let mut out: Vec<ArtifactMeta> = Vec::new();
    for m in &reg.models {
        for s in Scheme::ALL {
            let stem = format!("{}_{}", m.artifact, s.name());
            if out.iter().any(|a| a.stem == stem) {
                continue;
            }
            let shape = if m.batch > 1 { vec![m.batch, 16] } else { vec![16] };
            out.push(ArtifactMeta {
                stem: stem.clone(),
                hlo_path: format!("synthetic/{stem}.hlo.txt").into(),
                weights_path: format!("synthetic/{stem}.npz").into(),
                weight_keys: Vec::new(),
                model: m.artifact.to_string(),
                task: m.task.name().to_string(),
                scheme: s.name().to_string(),
                input: TensorSpec { shape, dtype: DType::F32 },
                outputs: vec![TensorSpec { shape: vec![10], dtype: DType::F32 }],
                params: (m.mparams * 1e6) as usize,
                flops: m.gflops * 1e9,
                weight_bytes: (m.mparams * 1e6 * s.bytes_per_param()) as usize,
                input_scale: None,
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::engine::random_input;

    fn loaded_stub() -> (StubEngine, ArtifactMeta) {
        let reg = Registry::paper();
        let manifest = synthetic_manifest(&reg);
        let meta = manifest[0].clone();
        let mut e = StubEngine::new();
        e.load(&meta).unwrap();
        (e, meta)
    }

    #[test]
    fn stub_engine_round_trip() {
        let (mut e, meta) = loaded_stub();
        assert!(e.is_loaded(&meta.stem));
        assert_eq!(e.loaded_count(), 1);
        let out = e.infer(&meta.stem, &random_input(&meta, 1)).unwrap();
        assert_eq!(out.len(), meta.outputs[0].numel());
        // validation mirrors the real engine's
        assert!(e.infer(&meta.stem, &Tensor::F32(vec![0.0; 3])).is_err());
        assert!(e.infer("nope", &random_input(&meta, 1)).is_err());
        e.unload(&meta.stem);
        assert!(!e.is_loaded(&meta.stem));
    }

    #[test]
    fn synthetic_manifest_covers_registry_routes() {
        let reg = Registry::paper();
        let manifest = synthetic_manifest(&reg);
        for m in &reg.models {
            for s in Scheme::ALL {
                assert!(
                    crate::runtime::artifact::find(&manifest, m.artifact, s.name()).is_some(),
                    "{} {} missing",
                    m.artifact,
                    s.name()
                );
            }
        }
    }

    #[test]
    fn transient_rate_tracks_probability() {
        let (e, meta) = loaded_stub();
        let mut inj = FaultInjector::new(e, 7);
        inj.set_default(FaultSpec::transient(0.10));
        let input = random_input(&meta, 1);
        let mut errors = 0usize;
        for _ in 0..2000 {
            if inj.infer(&meta.stem, &input).is_err() {
                errors += 1;
            }
        }
        let rate = errors as f64 / 2000.0;
        assert!((rate - 0.10).abs() < 0.03, "rate {rate}");
        assert_eq!(inj.stats.injected_errors as usize, errors);
        assert_eq!(inj.stats.calls, 2000);
    }

    #[test]
    fn injection_is_deterministic_per_seed() {
        let run = |seed: u64| -> Vec<bool> {
            let (e, meta) = loaded_stub();
            let mut inj = FaultInjector::new(e, seed);
            inj.set_default(FaultSpec::transient(0.25));
            let input = random_input(&meta, 1);
            (0..200).map(|_| inj.infer(&meta.stem, &input).is_err()).collect()
        };
        assert_eq!(run(42), run(42));
        assert_ne!(run(42), run(43));
    }

    #[test]
    fn outage_window_is_exact() {
        let (e, meta) = loaded_stub();
        let mut inj = FaultInjector::new(e, 1);
        inj.set_for(&meta.stem, FaultSpec::default().with_outage(3, 5));
        let input = random_input(&meta, 1);
        for call in 1u64..=8 {
            let r = inj.infer(&meta.stem, &input);
            if (3..=5).contains(&call) {
                let err = r.unwrap_err();
                let f = err.downcast_ref::<InjectedFault>().expect("typed fault");
                assert_eq!(f.kind, FaultKind::Outage);
                assert_eq!(f.call, call);
            } else {
                assert!(r.is_ok(), "call {call} should pass");
            }
        }
    }

    #[test]
    fn spikes_add_latency() {
        let (e, meta) = loaded_stub();
        let mut inj = FaultInjector::new(e, 5);
        inj.set_default(FaultSpec::default().with_spikes(1.0, 5.0));
        let input = random_input(&meta, 1);
        let t0 = std::time::Instant::now();
        inj.infer(&meta.stem, &input).unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(4));
        assert_eq!(inj.stats.injected_spikes, 1);
    }

    #[test]
    fn load_failures_inject() {
        let reg = Registry::paper();
        let meta = synthetic_manifest(&reg)[0].clone();
        let mut inj = FaultInjector::new(StubEngine::new(), 3);
        inj.set_default(FaultSpec::default().with_load_failures(1.0));
        let err = inj.load(&meta).unwrap_err();
        assert_eq!(
            err.downcast_ref::<InjectedFault>().unwrap().kind,
            FaultKind::Load
        );
        assert_eq!(inj.stats.failed_loads, 1);
        // clearing the spec lets the load through
        inj.set_default(FaultSpec::default());
        inj.load(&meta).unwrap();
        assert!(inj.is_loaded(&meta.stem));
    }

    #[test]
    fn per_stem_spec_overrides_default() {
        let reg = Registry::paper();
        let manifest = synthetic_manifest(&reg);
        let (a, b) = (manifest[0].clone(), manifest[1].clone());
        let mut inj = FaultInjector::new(StubEngine::new(), 9);
        inj.load(&a).unwrap();
        inj.load(&b).unwrap();
        inj.set_for(&a.stem, FaultSpec::transient(1.0));
        let ia = random_input(&a, 1);
        let ib = random_input(&b, 1);
        assert!(inj.infer(&a.stem, &ia).is_err());
        assert!(inj.infer(&b.stem, &ib).is_ok());
    }
}
