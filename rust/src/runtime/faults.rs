//! Fault-injection harness for the serving path (the robustness
//! substrate behind CARIn's "responsiveness under adversity" claim).
//!
//! Every executor sits behind the [`Inference`] trait; the
//! [`FaultInjector`] decorator wraps any executor and injects **seeded,
//! deterministic** faults with per-model probabilities:
//!
//! * *transient errors* — an inference call fails, the next may succeed;
//! * *latency spikes* — the call succeeds but burns extra wall-clock;
//! * *load failures* — compiling/uploading a model fails;
//! * *outage windows* — a per-stem call-index interval during which every
//!   call fails (a hard engine outage, used to force fallback switches);
//! * *hangs* — the call stalls for a long wall-clock interval before
//!   proceeding (a fail-slow executor), either probabilistically
//!   ([`FaultSpec::with_hangs`]) or for every call until an absolute
//!   wall-clock instant ([`FaultSpec::with_hang_until`]).
//!
//! Hangs are only survivable with supervision: the [`Watchdog`] wrapper
//! runs every wrapped call on a dedicated sacrificial thread with a
//! per-call deadline ([`Inference::set_call_deadline`]). When the
//! deadline fires the supervisor abandons the hung thread (its late
//! result is discarded via a generation counter and a dropped reply
//! channel) and surfaces [`crate::error::CarinError::Timeout`] /
//! [`FaultKind::Timeout`]; the next call respawns a fresh executor via
//! the factory and replays the resident model set.
//!
//! [`StubEngine`] is a PJRT-free executor (zero logits, optional fixed
//! latency) so chaos tests and benches run without `make artifacts`;
//! [`synthetic_manifest`] fabricates the matching artifact metadata for
//! the whole model registry.

use std::collections::HashMap;
use std::fmt;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::artifact::{ArtifactId, ArtifactMeta, DType, TensorSpec};
use super::engine::{InferenceEngine, Tensor};
use crate::error::CarinError;
use crate::util::{BufferPool, Rng};
use crate::zoo::{Registry, Scheme};

/// The executor abstraction the serving coordinator supervises. The real
/// PJRT engine, the stub engine and the fault injector all implement it,
/// so supervision and injection compose with any backend.
///
/// Models are addressed by interned [`ArtifactId`] handles (`Copy`, one
/// `u32`): the hot path never clones a stem `String`, and the id→stem
/// association is learned once at [`Inference::load`] time from the
/// `ArtifactMeta` (display names are only resolved back on cold error/
/// export paths).
pub trait Inference {
    /// Run one inference on a loaded model.
    fn infer(&mut self, route: ArtifactId, input: &Tensor) -> Result<Tensor>;
    /// Compile an artifact and make it resident under `route`.
    /// Idempotent per route.
    fn load(&mut self, route: ArtifactId, meta: &ArtifactMeta) -> Result<()>;
    /// Drop a resident model.
    fn unload(&mut self, route: ArtifactId);
    fn is_loaded(&self, route: ArtifactId) -> bool;
    /// Number of resident models.
    fn loaded_count(&self) -> usize;
    /// Injection counters, if this executor (or a decorator in its stack)
    /// injects faults. Lets pooled workers — whose engines are consumed by
    /// their owning thread — report injector activity back to tests.
    fn fault_stats(&self) -> Option<FaultStats> {
        None
    }
    /// Bound subsequent calls with a wall-clock deadline. Only
    /// supervising executors ([`Watchdog`]) act on it; plain executors
    /// ignore it and decorators ([`FaultInjector`]) forward it, so the
    /// coordinators can set per-task deadlines without knowing the
    /// executor stack. `None` removes the bound.
    fn set_call_deadline(&mut self, _deadline: Option<Duration>) {}
}

impl Inference for InferenceEngine {
    fn infer(&mut self, route: ArtifactId, input: &Tensor) -> Result<Tensor> {
        let stem = self
            .route_stem(route)
            .ok_or_else(|| anyhow!("{route} never loaded through this engine"))?;
        InferenceEngine::infer(self, stem, input)
    }

    fn load(&mut self, route: ArtifactId, meta: &ArtifactMeta) -> Result<()> {
        self.note_route(route, &meta.stem);
        InferenceEngine::load(self, meta)
    }

    fn unload(&mut self, route: ArtifactId) {
        if let Some(stem) = self.route_stem(route) {
            let stem = stem.to_string();
            InferenceEngine::unload(self, &stem)
        }
    }

    fn is_loaded(&self, route: ArtifactId) -> bool {
        self.route_stem(route).is_some_and(|s| InferenceEngine::is_loaded(self, s))
    }

    fn loaded_count(&self) -> usize {
        self.loaded().len()
    }
}

/// What kind of fault was injected (error taxonomy for reports/tests).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// One-shot execution failure; retrying may succeed.
    Transient,
    /// Hard outage window: every call in the window fails.
    Outage,
    /// Model load/compile failure.
    Load,
    /// A supervised call exceeded its watchdog deadline (fail-slow hang).
    Timeout,
}

impl FaultKind {
    pub fn name(self) -> &'static str {
        match self {
            FaultKind::Transient => "transient",
            FaultKind::Outage => "outage",
            FaultKind::Load => "load",
            FaultKind::Timeout => "timeout",
        }
    }
}

/// Classify an engine error into the fault taxonomy: watchdog timeouts
/// map to [`FaultKind::Timeout`]; injected faults report their own kind;
/// anything else (a real executor error) is `None`.
pub fn fault_kind_of(err: &anyhow::Error) -> Option<FaultKind> {
    if CarinError::find_in(err).is_some_and(CarinError::is_timeout) {
        return Some(FaultKind::Timeout);
    }
    err.downcast_ref::<InjectedFault>().map(|f| f.kind)
}

/// The error type injected faults surface as; supervised execution (and
/// tests) can `downcast_ref::<InjectedFault>()` to classify failures.
#[derive(Debug, Clone)]
pub struct InjectedFault {
    pub kind: FaultKind,
    pub stem: String,
    /// Per-stem call index at which the fault fired (1-based).
    pub call: u64,
}

impl fmt::Display for InjectedFault {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "injected {} fault on {} (call #{})",
            self.kind.name(),
            self.stem,
            self.call
        )
    }
}

impl std::error::Error for InjectedFault {}

/// Per-model fault probabilities and schedules. All fields default to
/// "no fault"; combine with the builder methods. `Copy`, so the per-call
/// spec lookup never allocates.
#[derive(Debug, Clone, Copy, Default)]
pub struct FaultSpec {
    /// Per-call probability of a transient execution error.
    pub transient_p: f64,
    /// Per-call probability of a latency spike.
    pub spike_p: f64,
    /// Injected extra latency per spike, ms.
    pub spike_ms: f64,
    /// Per-call probability that a `load()` fails.
    pub load_fail_p: f64,
    /// Inclusive per-stem call-index window `[from, to]` (1-based) during
    /// which every inference fails — a hard outage.
    pub outage: Option<(u64, u64)>,
    /// Per-call probability of a hang (the call stalls `hang_ms` before
    /// proceeding — a fail-slow executor, not an error).
    pub hang_p: f64,
    /// Stall duration per hang, ms.
    pub hang_ms: f64,
    /// If set, *every* call before this wall-clock instant hangs.
    pub hang_until: Option<Instant>,
}

impl FaultSpec {
    /// Only transient errors with probability `p`.
    pub fn transient(p: f64) -> FaultSpec {
        FaultSpec { transient_p: p, ..FaultSpec::default() }
    }

    /// Add latency spikes: probability `p`, `ms` extra wall-clock each.
    pub fn with_spikes(mut self, p: f64, ms: f64) -> FaultSpec {
        self.spike_p = p;
        self.spike_ms = ms;
        self
    }

    /// Add load failures with probability `p`.
    pub fn with_load_failures(mut self, p: f64) -> FaultSpec {
        self.load_fail_p = p;
        self
    }

    /// Add a hard outage over the inclusive call window `[from, to]`.
    pub fn with_outage(mut self, from: u64, to: u64) -> FaultSpec {
        self.outage = Some((from, to));
        self
    }

    /// Add probabilistic hangs: with probability `p` a call stalls `ms`
    /// of wall-clock before proceeding. The call itself still succeeds
    /// (late), so only a [`Watchdog`] deadline turns it into a fault.
    pub fn with_hangs(mut self, p: f64, ms: f64) -> FaultSpec {
        self.hang_p = p;
        self.hang_ms = ms;
        self
    }

    /// Hang *every* call (each stalling `ms`) until the absolute
    /// wall-clock instant `until`. Unlike a call-index outage window
    /// this survives watchdog respawns — a freshly-built injector has
    /// reset call counts but the wall clock keeps running — so the hang
    /// window genuinely ends and recovery probes can heal the engine.
    pub fn with_hang_until(mut self, until: Instant, ms: f64) -> FaultSpec {
        self.hang_until = Some(until);
        self.hang_ms = ms;
        self
    }
}

/// Running injection counters (what the harness actually did).
#[derive(Debug, Clone, Default)]
pub struct FaultStats {
    pub calls: u64,
    pub injected_errors: u64,
    pub injected_spikes: u64,
    pub failed_loads: u64,
    pub injected_hangs: u64,
}

impl FaultStats {
    /// Accumulate another executor's counters (per-worker stats reduce
    /// into one report-time total).
    pub fn absorb(&mut self, other: &FaultStats) {
        self.calls += other.calls;
        self.injected_errors += other.injected_errors;
        self.injected_spikes += other.injected_spikes;
        self.failed_loads += other.failed_loads;
        self.injected_hangs += other.injected_hangs;
    }
}

/// Deterministic fault-injecting decorator around any [`Inference`]
/// executor. Faults are drawn from a seeded [`Rng`], so a given seed and
/// call sequence replays the exact same fault schedule.
pub struct FaultInjector<E: Inference> {
    inner: E,
    rng: Rng,
    default_spec: FaultSpec,
    /// Specs stay keyed by stem so tests/benches can target a model by
    /// name before any route ids exist; resolved per call through
    /// `names` without allocating.
    per_stem: HashMap<String, FaultSpec>,
    /// Route → stem associations learned at `load` time.
    names: HashMap<ArtifactId, String>,
    /// Per-route inference call counts (1-based after increment).
    calls: HashMap<ArtifactId, u64>,
    pub stats: FaultStats,
}

impl<E: Inference> FaultInjector<E> {
    pub fn new(inner: E, seed: u64) -> FaultInjector<E> {
        FaultInjector {
            inner,
            rng: Rng::new(seed ^ 0xFA17_FA17_FA17_FA17),
            default_spec: FaultSpec::default(),
            per_stem: HashMap::new(),
            names: HashMap::new(),
            calls: HashMap::new(),
            stats: FaultStats::default(),
        }
    }

    /// Fault spec applied to stems without a dedicated entry.
    pub fn set_default(&mut self, spec: FaultSpec) {
        self.default_spec = spec;
    }

    /// Fault spec for one model stem (overrides the default).
    pub fn set_for(&mut self, stem: &str, spec: FaultSpec) {
        self.per_stem.insert(stem.to_string(), spec);
    }

    pub fn inner(&self) -> &E {
        &self.inner
    }

    pub fn inner_mut(&mut self) -> &mut E {
        &mut self.inner
    }

    pub fn into_inner(self) -> E {
        self.inner
    }

    /// Inference calls observed for a route so far.
    pub fn calls_for(&self, route: ArtifactId) -> u64 {
        self.calls.get(&route).copied().unwrap_or(0)
    }

    /// Stem for error payloads/logs; falls back to the `route#N` display
    /// form for routes that never loaded. Cold path only.
    fn display_name(&self, route: ArtifactId) -> String {
        self.names.get(&route).cloned().unwrap_or_else(|| route.to_string())
    }

    fn spec_for(&self, route: ArtifactId) -> FaultSpec {
        self.names
            .get(&route)
            .and_then(|stem| self.per_stem.get(stem))
            .copied()
            .unwrap_or(self.default_spec)
    }
}

impl<E: Inference> Inference for FaultInjector<E> {
    fn infer(&mut self, route: ArtifactId, input: &Tensor) -> Result<Tensor> {
        let call = {
            let c = self.calls.entry(route).or_insert(0);
            *c += 1;
            *c
        };
        self.stats.calls += 1;
        let spec = self.spec_for(route);
        if let Some((from, to)) = spec.outage {
            if call >= from && call <= to {
                self.stats.injected_errors += 1;
                let stem = self.display_name(route);
                crate::log_trace!("inject outage fault on {stem} (call #{call})");
                return Err(InjectedFault { kind: FaultKind::Outage, stem, call }.into());
            }
        }
        let hang = spec.hang_until.is_some_and(|until| Instant::now() < until)
            || (spec.hang_p > 0.0 && self.rng.chance(spec.hang_p));
        if hang {
            self.stats.injected_hangs += 1;
            crate::log_trace!(
                "inject hang on {} (call #{call}, {:.0} ms)",
                self.display_name(route),
                spec.hang_ms
            );
            std::thread::sleep(Duration::from_secs_f64(spec.hang_ms.max(0.0) / 1000.0));
        }
        if spec.transient_p > 0.0 && self.rng.chance(spec.transient_p) {
            self.stats.injected_errors += 1;
            let stem = self.display_name(route);
            crate::log_trace!("inject transient fault on {stem} (call #{call})");
            return Err(InjectedFault { kind: FaultKind::Transient, stem, call }.into());
        }
        if spec.spike_p > 0.0 && self.rng.chance(spec.spike_p) {
            self.stats.injected_spikes += 1;
            std::thread::sleep(Duration::from_secs_f64(spec.spike_ms.max(0.0) / 1000.0));
        }
        self.inner.infer(route, input)
    }

    fn load(&mut self, route: ArtifactId, meta: &ArtifactMeta) -> Result<()> {
        // learn the association before attempting the load, so faults on
        // a route that never loaded still carry the stem name
        if self.names.get(&route).map(String::as_str) != Some(meta.stem.as_str()) {
            self.names.insert(route, meta.stem.clone());
        }
        let spec = self.per_stem.get(&meta.stem).copied().unwrap_or(self.default_spec);
        if spec.load_fail_p > 0.0 && self.rng.chance(spec.load_fail_p) {
            self.stats.failed_loads += 1;
            return Err(InjectedFault {
                kind: FaultKind::Load,
                stem: meta.stem.clone(),
                call: self.calls_for(route),
            }
            .into());
        }
        self.inner.load(route, meta)
    }

    fn unload(&mut self, route: ArtifactId) {
        self.inner.unload(route)
    }

    fn is_loaded(&self, route: ArtifactId) -> bool {
        self.inner.is_loaded(route)
    }

    fn loaded_count(&self) -> usize {
        self.inner.loaded_count()
    }

    fn fault_stats(&self) -> Option<FaultStats> {
        let mut stats = self.stats.clone();
        if let Some(inner) = self.inner.fault_stats() {
            stats.absorb(&inner);
        }
        Some(stats)
    }

    fn set_call_deadline(&mut self, deadline: Option<Duration>) {
        self.inner.set_call_deadline(deadline)
    }
}

/// Supervision counters for a [`Watchdog`].
#[derive(Debug, Clone, Copy, Default)]
pub struct WatchdogStats {
    /// Calls whose deadline fired (the executor thread was abandoned).
    pub timeouts: u64,
    /// Fresh executor threads spawned after an abandonment.
    pub respawns: u64,
}

/// Work shipped to the sacrificial executor thread. Replies are tagged
/// with the generation the job was issued under, so a reply from before
/// a respawn can never be mistaken for the current call's result.
enum Job {
    /// `input` is `Arc`-backed, so shipping it across the channel bumps
    /// a refcount instead of deep-copying the payload.
    Infer { route: ArtifactId, input: Tensor, generation: u64 },
    Load { route: ArtifactId, meta: Box<ArtifactMeta>, generation: u64 },
    Unload { route: ArtifactId },
    Stats { generation: u64 },
}

enum Reply {
    Ready { generation: u64, result: Result<()> },
    Infer { generation: u64, result: Result<Tensor> },
    Load { generation: u64, result: Result<()> },
    Stats { generation: u64, stats: Option<FaultStats> },
}

impl Reply {
    fn generation(&self) -> u64 {
        match self {
            Reply::Ready { generation, .. }
            | Reply::Infer { generation, .. }
            | Reply::Load { generation, .. }
            | Reply::Stats { generation, .. } => *generation,
        }
    }
}

/// Channel pair linking the supervisor to the live executor thread.
struct Link {
    tx: mpsc::Sender<Job>,
    rx: mpsc::Receiver<Reply>,
}

/// How long a handshake / model load may take before the supervisor
/// gives up on the executor thread (loads compile artifacts, so they
/// get far more slack than inference deadlines).
const WATCHDOG_SETUP_WAIT: Duration = Duration::from_secs(30);

/// Watchdog-based timeout supervision: runs every wrapped call on a
/// dedicated sacrificial thread with a per-call wall-clock deadline.
///
/// The wrapped executor is built *inside* that thread by the factory
/// closure (so `E` never crosses a thread boundary and needs no `Send`
/// bound). When a call exceeds the deadline set via
/// [`Inference::set_call_deadline`]:
///
/// 1. the call fails with [`CarinError::Timeout`] (classified as
///    [`FaultKind::Timeout`] by [`fault_kind_of`]), which supervision
///    upstream counts toward consecutive-failure fault raising;
/// 2. the hung thread is **abandoned** — its reply channel is dropped
///    and the generation counter advances, so a late completion can
///    never be delivered to a newer request; the thread dies quietly
///    once its stalled call finally returns;
/// 3. the next call respawns a fresh executor via the factory and
///    replays the resident model set (mirrored supervisor-side), so the
///    replacement is route-complete before it executes anything.
///
/// Fault-injection counters accumulated on an abandoned thread are lost
/// with it; [`Inference::fault_stats`] reports the live thread's view.
pub struct Watchdog<E: Inference + 'static> {
    factory: Arc<dyn Fn() -> Result<E> + Send + Sync>,
    link: Option<Link>,
    /// Bumped on every (re)spawn; replies from older generations are
    /// discarded unread.
    generation: u64,
    deadline: Option<Duration>,
    /// Supervisor-side mirror of the resident set, replayed into every
    /// respawned executor.
    resident: HashMap<ArtifactId, ArtifactMeta>,
    pub stats: WatchdogStats,
}

impl<E: Inference + 'static> Watchdog<E> {
    /// Wrap the executors produced by `factory` with timeout
    /// supervision. Spawns the first executor thread eagerly so factory
    /// errors surface here rather than on the first call.
    pub fn new<F>(factory: F) -> Result<Watchdog<E>>
    where
        F: Fn() -> Result<E> + Send + Sync + 'static,
    {
        let mut dog = Watchdog {
            factory: Arc::new(factory),
            link: None,
            generation: 0,
            deadline: None,
            resident: HashMap::new(),
            stats: WatchdogStats::default(),
        };
        dog.ensure_thread()?;
        Ok(dog)
    }

    /// Builder-style deadline (same as [`Inference::set_call_deadline`]).
    pub fn with_deadline(mut self, deadline: Duration) -> Watchdog<E> {
        self.deadline = Some(deadline);
        self
    }

    /// The active per-call deadline, if any.
    pub fn deadline(&self) -> Option<Duration> {
        self.deadline
    }

    /// Spawn (or respawn) the executor thread and replay the resident
    /// set. No-op when a live thread exists.
    fn ensure_thread(&mut self) -> Result<()> {
        if self.link.is_some() {
            return Ok(());
        }
        if self.generation > 0 {
            self.stats.respawns += 1;
        }
        self.generation += 1;
        let generation = self.generation;
        let (jtx, jrx) = mpsc::channel::<Job>();
        let (rtx, rrx) = mpsc::channel::<Reply>();
        let factory = Arc::clone(&self.factory);
        std::thread::Builder::new()
            .name(format!("carin-watchdog-{generation}"))
            .spawn(move || {
                let mut engine = match factory() {
                    Ok(e) => {
                        let _ = rtx.send(Reply::Ready { generation, result: Ok(()) });
                        e
                    }
                    Err(e) => {
                        let _ = rtx.send(Reply::Ready { generation, result: Err(e) });
                        return;
                    }
                };
                while let Ok(job) = jrx.recv() {
                    let reply = match job {
                        Job::Infer { route, input, generation } => Reply::Infer {
                            generation,
                            result: engine.infer(route, &input),
                        },
                        Job::Load { route, meta, generation } => Reply::Load {
                            generation,
                            result: engine.load(route, &meta),
                        },
                        Job::Unload { route } => {
                            engine.unload(route);
                            continue;
                        }
                        Job::Stats { generation } => Reply::Stats {
                            generation,
                            stats: engine.fault_stats(),
                        },
                    };
                    if rtx.send(reply).is_err() {
                        // abandoned: the supervisor moved on to a new
                        // generation while this call was stalled
                        return;
                    }
                }
            })
            .map_err(|e| anyhow!("watchdog: failed to spawn executor thread: {e}"))?;
        let link = Link { tx: jtx, rx: rrx };
        match link.rx.recv_timeout(WATCHDOG_SETUP_WAIT) {
            Ok(Reply::Ready { result: Ok(()), .. }) => {}
            Ok(Reply::Ready { result: Err(e), .. }) => {
                return Err(e.context("watchdog: executor factory failed"));
            }
            Ok(_) => return Err(anyhow!("watchdog: unexpected reply during handshake")),
            Err(_) => return Err(anyhow!("watchdog: executor thread never came up")),
        }
        // replay the resident set so the fresh executor is route-complete
        for (&route, meta) in self.resident.iter() {
            link.tx
                .send(Job::Load { route, meta: Box::new(meta.clone()), generation })
                .map_err(|_| anyhow!("watchdog: executor thread died during replay"))?;
            match link.rx.recv_timeout(WATCHDOG_SETUP_WAIT) {
                Ok(Reply::Load { result: Ok(()), .. }) => {}
                Ok(Reply::Load { result: Err(e), .. }) => {
                    return Err(e.context(format!("watchdog: replaying {} failed", meta.stem)));
                }
                Ok(_) => return Err(anyhow!("watchdog: unexpected reply during replay")),
                Err(_) => {
                    return Err(anyhow!("watchdog: executor hung replaying {}", meta.stem))
                }
            }
        }
        self.link = Some(link);
        Ok(())
    }

    /// Wait for the current generation's reply, discarding stale ones.
    /// On deadline expiry the link is dropped (abandoning the thread)
    /// and the caller maps the timeout to an error.
    fn await_reply(&mut self, wait: Option<Duration>) -> Result<Reply, mpsc::RecvTimeoutError> {
        let started = Instant::now();
        loop {
            let link = self.link.as_ref().ok_or(mpsc::RecvTimeoutError::Disconnected)?;
            let reply = match wait {
                Some(d) => {
                    let left = d.checked_sub(started.elapsed()).unwrap_or(Duration::ZERO);
                    link.rx.recv_timeout(left)?
                }
                None => link.rx.recv().map_err(|_| mpsc::RecvTimeoutError::Disconnected)?,
            };
            if reply.generation() == self.generation {
                return Ok(reply);
            }
            // stale generation: a reply raced an abandonment; drop it
        }
    }

    /// Abandon the (presumed hung) executor thread and surface the
    /// timeout as a typed error. Display names resolve through the
    /// resident mirror — this is a cold path; the hot path only ever
    /// moved the `Copy` route id.
    fn on_timeout(&mut self, route: ArtifactId, deadline: Duration) -> anyhow::Error {
        self.stats.timeouts += 1;
        // dropping the link closes the reply channel: the stalled call's
        // eventual result has nowhere to go, and the thread exits on its
        // failed send
        self.link = None;
        let stem = self
            .resident
            .get(&route)
            .map(|m| m.stem.clone())
            .unwrap_or_else(|| route.to_string());
        crate::log_debug!(
            "watchdog: {stem} exceeded {:.1} ms deadline, executor thread abandoned",
            deadline.as_secs_f64() * 1000.0
        );
        anyhow::Error::new(CarinError::Timeout {
            stem,
            deadline_ms: deadline.as_secs_f64() * 1000.0,
        })
    }
}

impl<E: Inference + 'static> Inference for Watchdog<E> {
    fn infer(&mut self, route: ArtifactId, input: &Tensor) -> Result<Tensor> {
        self.ensure_thread()?;
        let generation = self.generation;
        self.link
            .as_ref()
            .expect("link after ensure_thread")
            .tx
            // the tensor clone is an Arc bump, not a payload copy
            .send(Job::Infer { route, input: input.clone(), generation })
            .map_err(|_| anyhow!("watchdog: executor thread terminated"))?;
        match self.await_reply(self.deadline) {
            Ok(Reply::Infer { result, .. }) => result,
            Ok(_) => Err(anyhow!("watchdog: mismatched reply for infer")),
            Err(mpsc::RecvTimeoutError::Timeout) => {
                let d = self.deadline.expect("timeout implies a deadline");
                Err(self.on_timeout(route, d))
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                self.link = None;
                Err(anyhow!("watchdog: executor thread died mid-call"))
            }
        }
    }

    fn load(&mut self, route: ArtifactId, meta: &ArtifactMeta) -> Result<()> {
        self.ensure_thread()?;
        let generation = self.generation;
        self.link
            .as_ref()
            .expect("link after ensure_thread")
            .tx
            .send(Job::Load { route, meta: Box::new(meta.clone()), generation })
            .map_err(|_| anyhow!("watchdog: executor thread terminated"))?;
        match self.await_reply(Some(WATCHDOG_SETUP_WAIT)) {
            Ok(Reply::Load { result, .. }) => {
                if result.is_ok() {
                    self.resident.insert(route, meta.clone());
                }
                result
            }
            Ok(_) => Err(anyhow!("watchdog: mismatched reply for load")),
            Err(mpsc::RecvTimeoutError::Timeout) => {
                Err(self.on_timeout(route, WATCHDOG_SETUP_WAIT))
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                self.link = None;
                Err(anyhow!("watchdog: executor thread died mid-load"))
            }
        }
    }

    fn unload(&mut self, route: ArtifactId) {
        self.resident.remove(&route);
        if let Some(link) = &self.link {
            let _ = link.tx.send(Job::Unload { route });
        }
    }

    fn is_loaded(&self, route: ArtifactId) -> bool {
        self.resident.contains_key(&route)
    }

    fn loaded_count(&self) -> usize {
        self.resident.len()
    }

    fn fault_stats(&self) -> Option<FaultStats> {
        // counters on an abandoned thread are lost with it; query the
        // live one (bounded, in case it is mid-stall)
        let link = self.link.as_ref()?;
        let generation = self.generation;
        link.tx.send(Job::Stats { generation }).ok()?;
        loop {
            match link.rx.recv_timeout(WATCHDOG_SETUP_WAIT) {
                Ok(Reply::Stats { generation: g, stats }) if g == generation => return stats,
                Ok(_) => continue,
                Err(_) => return None,
            }
        }
    }

    fn set_call_deadline(&mut self, deadline: Option<Duration>) {
        self.deadline = deadline;
    }
}

/// PJRT-free executor: validates requests against the artifact metadata
/// and returns an all-zero logits tensor, optionally burning `exec_ms`
/// of wall-clock per call. Lets chaos tests, examples and benches run
/// the full coordinator stack without `make artifacts`.
///
/// Output tensors lease recycled buffers from an internal
/// [`BufferPool`], so steady-state stub serving allocates nothing per
/// call (the property the counting-allocator test pins down).
#[derive(Debug, Default)]
pub struct StubEngine {
    models: HashMap<ArtifactId, ArtifactMeta>,
    /// Simulated execution latency per call, ms (0 = instant).
    pub exec_ms: f64,
    out_pool: BufferPool,
}

impl StubEngine {
    pub fn new() -> StubEngine {
        StubEngine::default()
    }

    pub fn with_latency(exec_ms: f64) -> StubEngine {
        StubEngine { exec_ms, ..StubEngine::default() }
    }

    /// Output buffer-pool counters (for the memory-path telemetry).
    pub fn out_pool_stats(&self) -> crate::util::BufPoolStats {
        self.out_pool.sweep_returns();
        self.out_pool.stats()
    }
}

impl Inference for StubEngine {
    fn infer(&mut self, route: ArtifactId, input: &Tensor) -> Result<Tensor> {
        let meta = self
            .models
            .get(&route)
            .ok_or_else(|| anyhow!("model {route} not loaded"))?;
        if input.dtype() != meta.input.dtype {
            return Err(anyhow!(
                "{}: input dtype {:?} != manifest {:?}",
                meta.stem,
                input.dtype(),
                meta.input.dtype
            ));
        }
        if input.len() != meta.input.numel() {
            return Err(anyhow!(
                "{}: input numel {} != manifest {}",
                meta.stem,
                input.len(),
                meta.input.numel()
            ));
        }
        let n = meta.outputs[0].numel();
        if self.exec_ms > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(self.exec_ms / 1000.0));
        }
        Ok(Tensor::F32(self.out_pool.lease_zeroed(n)))
    }

    fn load(&mut self, route: ArtifactId, meta: &ArtifactMeta) -> Result<()> {
        self.models.entry(route).or_insert_with(|| meta.clone());
        Ok(())
    }

    fn unload(&mut self, route: ArtifactId) {
        self.models.remove(&route);
    }

    fn is_loaded(&self, route: ArtifactId) -> bool {
        self.models.contains_key(&route)
    }

    fn loaded_count(&self) -> usize {
        self.models.len()
    }
}

/// Fabricate an artifact manifest covering every (artifact, scheme) pair
/// of the registry, for [`StubEngine`]-backed runs. Shapes are small and
/// rank ≤ 2 (no batched rank-4 inputs) so payload generation stays cheap.
pub fn synthetic_manifest(reg: &Registry) -> Vec<ArtifactMeta> {
    let mut out: Vec<ArtifactMeta> = Vec::new();
    for m in &reg.models {
        for s in Scheme::ALL {
            let stem = format!("{}_{}", m.artifact, s.name());
            if out.iter().any(|a| a.stem == stem) {
                continue;
            }
            let shape = if m.batch > 1 { vec![m.batch, 16] } else { vec![16] };
            out.push(ArtifactMeta {
                stem: stem.clone(),
                hlo_path: format!("synthetic/{stem}.hlo.txt").into(),
                weights_path: format!("synthetic/{stem}.npz").into(),
                weight_keys: Vec::new(),
                model: m.artifact.to_string(),
                task: m.task.name().to_string(),
                scheme: s.name().to_string(),
                input: TensorSpec { shape, dtype: DType::F32 },
                outputs: vec![TensorSpec { shape: vec![10], dtype: DType::F32 }],
                params: (m.mparams * 1e6) as usize,
                flops: m.gflops * 1e9,
                weight_bytes: (m.mparams * 1e6 * s.bytes_per_param()) as usize,
                input_scale: None,
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::engine::random_input;

    /// Route id of the first synthetic-manifest entry (ids are manifest
    /// indices).
    const R0: ArtifactId = ArtifactId(0);
    const R1: ArtifactId = ArtifactId(1);

    fn loaded_stub() -> (StubEngine, ArtifactMeta) {
        let reg = Registry::paper();
        let manifest = synthetic_manifest(&reg);
        let meta = manifest[0].clone();
        let mut e = StubEngine::new();
        e.load(R0, &meta).unwrap();
        (e, meta)
    }

    #[test]
    fn stub_engine_round_trip() {
        let (mut e, meta) = loaded_stub();
        assert!(e.is_loaded(R0));
        assert_eq!(e.loaded_count(), 1);
        let out = e.infer(R0, &random_input(&meta, 1)).unwrap();
        assert_eq!(out.len(), meta.outputs[0].numel());
        // validation mirrors the real engine's
        assert!(e.infer(R0, &Tensor::F32(vec![0.0; 3].into())).is_err());
        assert!(e.infer(ArtifactId(999), &random_input(&meta, 1)).is_err());
        e.unload(R0);
        assert!(!e.is_loaded(R0));
    }

    #[test]
    fn stub_outputs_recycle_pooled_buffers() {
        let (mut e, meta) = loaded_stub();
        let input = random_input(&meta, 1);
        let first = e.infer(R0, &input).unwrap();
        let Tensor::F32(buf) = &first else { unreachable!() };
        let ptr = buf.as_slice().as_ptr();
        drop(first);
        let second = e.infer(R0, &input).unwrap();
        let Tensor::F32(buf) = &second else { unreachable!() };
        assert!(std::ptr::eq(ptr, buf.as_slice().as_ptr()), "output slot recycled");
        let stats = e.out_pool_stats();
        assert_eq!((stats.hits, stats.misses), (1, 1));
    }

    #[test]
    fn synthetic_manifest_covers_registry_routes() {
        let reg = Registry::paper();
        let manifest = synthetic_manifest(&reg);
        for m in &reg.models {
            for s in Scheme::ALL {
                assert!(
                    crate::runtime::artifact::find(&manifest, m.artifact, s.name()).is_some(),
                    "{} {} missing",
                    m.artifact,
                    s.name()
                );
            }
        }
    }

    #[test]
    fn transient_rate_tracks_probability() {
        let (e, meta) = loaded_stub();
        let mut inj = FaultInjector::new(e, 7);
        inj.set_default(FaultSpec::transient(0.10));
        let input = random_input(&meta, 1);
        let mut errors = 0usize;
        for _ in 0..2000 {
            if inj.infer(R0, &input).is_err() {
                errors += 1;
            }
        }
        let rate = errors as f64 / 2000.0;
        assert!((rate - 0.10).abs() < 0.03, "rate {rate}");
        assert_eq!(inj.stats.injected_errors as usize, errors);
        assert_eq!(inj.stats.calls, 2000);
        assert_eq!(inj.calls_for(R0), 2000);
    }

    #[test]
    fn injection_is_deterministic_per_seed() {
        let run = |seed: u64| -> Vec<bool> {
            let (e, meta) = loaded_stub();
            let mut inj = FaultInjector::new(e, seed);
            inj.set_default(FaultSpec::transient(0.25));
            let input = random_input(&meta, 1);
            (0..200).map(|_| inj.infer(R0, &input).is_err()).collect()
        };
        assert_eq!(run(42), run(42));
        assert_ne!(run(42), run(43));
    }

    #[test]
    fn outage_window_is_exact() {
        let (e, meta) = loaded_stub();
        let mut inj = FaultInjector::new(e, 1);
        // the stem-keyed spec resolves through the route association
        // learned when the injector sees the load
        inj.load(R0, &meta).unwrap();
        inj.set_for(&meta.stem, FaultSpec::default().with_outage(3, 5));
        let input = random_input(&meta, 1);
        for call in 1u64..=8 {
            let r = inj.infer(R0, &input);
            if (3..=5).contains(&call) {
                let err = r.unwrap_err();
                let f = err.downcast_ref::<InjectedFault>().expect("typed fault");
                assert_eq!(f.kind, FaultKind::Outage);
                assert_eq!(f.call, call);
                assert_eq!(f.stem, meta.stem, "fault names the stem, not the id");
            } else {
                assert!(r.is_ok(), "call {call} should pass");
            }
        }
    }

    #[test]
    fn spikes_add_latency() {
        let (e, meta) = loaded_stub();
        let mut inj = FaultInjector::new(e, 5);
        inj.set_default(FaultSpec::default().with_spikes(1.0, 5.0));
        let input = random_input(&meta, 1);
        let t0 = std::time::Instant::now();
        inj.infer(R0, &input).unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(4));
        assert_eq!(inj.stats.injected_spikes, 1);
    }

    #[test]
    fn load_failures_inject() {
        let reg = Registry::paper();
        let meta = synthetic_manifest(&reg)[0].clone();
        let mut inj = FaultInjector::new(StubEngine::new(), 3);
        inj.set_default(FaultSpec::default().with_load_failures(1.0));
        let err = inj.load(R0, &meta).unwrap_err();
        assert_eq!(
            err.downcast_ref::<InjectedFault>().unwrap().kind,
            FaultKind::Load
        );
        assert_eq!(inj.stats.failed_loads, 1);
        // clearing the spec lets the load through
        inj.set_default(FaultSpec::default());
        inj.load(R0, &meta).unwrap();
        assert!(inj.is_loaded(R0));
    }

    #[test]
    fn hangs_stall_but_succeed() {
        let (e, meta) = loaded_stub();
        let mut inj = FaultInjector::new(e, 13);
        inj.set_default(FaultSpec::default().with_hangs(1.0, 30.0));
        let input = random_input(&meta, 1);
        let t0 = std::time::Instant::now();
        // without a watchdog a hang is just a very late success
        inj.infer(R0, &input).unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(25));
        assert_eq!(inj.stats.injected_hangs, 1);
        assert_eq!(inj.fault_stats().unwrap().injected_hangs, 1);
    }

    #[test]
    fn watchdog_times_out_abandons_and_respawns() {
        let reg = Registry::paper();
        let meta = synthetic_manifest(&reg)[0].clone();
        let stem = meta.stem.clone();
        let hang_until = Instant::now() + Duration::from_millis(150);
        let spec_stem = stem.clone();
        let mut dog = Watchdog::new(move || {
            let mut inj = FaultInjector::new(StubEngine::new(), 11);
            inj.set_for(&spec_stem, FaultSpec::default().with_hang_until(hang_until, 5_000.0));
            Ok(inj)
        })
        .unwrap();
        dog.set_call_deadline(Some(Duration::from_millis(25)));
        dog.load(R0, &meta).unwrap();
        let input = random_input(&meta, 1);

        let err = dog.infer(R0, &input).unwrap_err();
        let typed = CarinError::find_in(&err).expect("typed timeout in chain");
        assert!(typed.is_timeout());
        // the timeout's display name resolves through the resident set
        assert!(err.to_string().contains(&stem), "{err:#}");
        assert_eq!(fault_kind_of(&err), Some(FaultKind::Timeout));
        assert_eq!(dog.stats.timeouts, 1);
        // the mirror survives the abandonment, so the respawned executor
        // will be route-complete
        assert!(dog.is_loaded(R0));
        assert_eq!(dog.loaded_count(), 1);

        // after the wall-clock hang window ends, the next call respawns
        // a fresh executor, replays the resident set and succeeds
        std::thread::sleep(Duration::from_millis(160));
        let out = dog.infer(R0, &input).unwrap();
        assert_eq!(out.len(), meta.outputs[0].numel());
        assert_eq!(dog.stats.respawns, 1);
    }

    #[test]
    fn watchdog_late_result_never_unblocks_newer_calls() {
        let reg = Registry::paper();
        let manifest = synthetic_manifest(&reg);
        let (a, b) = (manifest[0].clone(), manifest[1].clone());
        let hang_stem = a.stem.clone();
        let mut dog = Watchdog::new(move || {
            let mut inj = FaultInjector::new(StubEngine::new(), 3);
            // stem A hangs on every call, far longer than the deadline;
            // stem B is clean
            inj.set_for(&hang_stem, FaultSpec::default().with_hangs(1.0, 500.0));
            Ok(inj)
        })
        .unwrap();
        dog.set_call_deadline(Some(Duration::from_millis(20)));
        dog.load(R0, &a).unwrap();
        dog.load(R1, &b).unwrap();
        let err = dog.infer(R0, &random_input(&a, 1)).unwrap_err();
        assert_eq!(fault_kind_of(&err), Some(FaultKind::Timeout));
        // the very next call runs on a fresh thread immediately — it is
        // not queued behind the stalled call, and the stalled call's
        // eventual (discarded) result can never surface here
        let t0 = Instant::now();
        let out = dog.infer(R1, &random_input(&b, 1)).unwrap();
        assert!(t0.elapsed() < Duration::from_millis(250), "stalled behind hung call");
        assert_eq!(out.len(), b.outputs[0].numel());
        assert_eq!(dog.stats.timeouts, 1);
        assert_eq!(dog.stats.respawns, 1);
    }

    #[test]
    fn watchdog_without_deadline_passes_through() {
        let reg = Registry::paper();
        let meta = synthetic_manifest(&reg)[0].clone();
        let mut dog = Watchdog::new(|| Ok(StubEngine::new())).unwrap();
        dog.load(R0, &meta).unwrap();
        let out = dog.infer(R0, &random_input(&meta, 1)).unwrap();
        assert_eq!(out.len(), meta.outputs[0].numel());
        assert_eq!(dog.stats.timeouts, 0);
        assert_eq!(dog.stats.respawns, 0);
        // fault stats forward through the sacrificial thread
        assert!(dog.fault_stats().is_none()); // StubEngine has none
        dog.unload(R0);
        assert!(!dog.is_loaded(R0));
    }

    #[test]
    fn watchdog_surfaces_factory_failure() {
        let err = Watchdog::<StubEngine>::new(|| Err(anyhow!("no device"))).unwrap_err();
        assert!(err.to_string().contains("factory failed"), "{err:#}");
    }

    #[test]
    fn per_stem_spec_overrides_default() {
        let reg = Registry::paper();
        let manifest = synthetic_manifest(&reg);
        let (a, b) = (manifest[0].clone(), manifest[1].clone());
        let mut inj = FaultInjector::new(StubEngine::new(), 9);
        inj.load(R0, &a).unwrap();
        inj.load(R1, &b).unwrap();
        inj.set_for(&a.stem, FaultSpec::transient(1.0));
        let ia = random_input(&a, 1);
        let ib = random_input(&b, 1);
        assert!(inj.infer(R0, &ia).is_err());
        assert!(inj.infer(R1, &ib).is_ok());
    }
}
