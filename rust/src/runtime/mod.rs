//! PJRT runtime (the request-path executor).
//!
//! Loads the AOT artifacts produced by `python/compile/aot.py` — HLO
//! **text** plus an `.npz` of scheme-transformed weights — compiles them
//! once on the PJRT CPU client, uploads the weights as device buffers,
//! and serves inferences with zero python involvement.
//!
//! Interchange gotchas (see /opt/xla-example/README.md): HLO text, not
//! serialized protos (xla_extension 0.5.1 rejects jax >= 0.5's 64-bit
//! instruction ids); computations are lowered with `return_tuple=True`,
//! so outputs unwrap with `to_tuple1`.

pub mod artifact;
pub mod engine;
pub mod faults;

pub use artifact::{load_manifest, ArtifactId, ArtifactMeta, DType};
pub use engine::{InferenceEngine, LoadedModel, Tensor};
pub use faults::{
    fault_kind_of, synthetic_manifest, FaultInjector, FaultKind, FaultSpec, FaultStats,
    Inference, InjectedFault, StubEngine, Watchdog, WatchdogStats,
};
