//! The inference engine: PJRT CPU client + compiled executables +
//! pre-uploaded weight buffers. This is the hot path — per request the
//! only work is one host→device input upload, one `execute_b`, and one
//! device→host readback.

use std::collections::HashMap;
use std::sync::Arc;

use std::time::Instant;

use anyhow::{anyhow, Context, Result};
use xla::FromRawBytes;

use super::artifact::{ArtifactMeta, DType};
use crate::util::{BufferPool, TensorBuf};

/// A host-side tensor crossing the engine boundary.
///
/// Every variant is reference-counted: `clone()` bumps an `Arc` instead
/// of deep-copying the payload, so a tensor can cross the watchdog
/// channel, sit in a batch and reach the engine as the same buffer (see
/// ROADMAP "Memory path"). `F32` — the serving-path dtype — is a
/// [`TensorBuf`], which additionally recycles through a
/// [`BufferPool`]. Construct from plain vectors with
/// `Tensor::F32(v.into())`.
#[derive(Debug, Clone)]
pub enum Tensor {
    F32(TensorBuf),
    I32(Arc<Vec<i32>>),
    I8(Arc<Vec<i8>>),
}

impl Tensor {
    pub fn dtype(&self) -> DType {
        match self {
            Tensor::F32(_) => DType::F32,
            Tensor::I32(_) => DType::I32,
            Tensor::I8(_) => DType::I8,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Tensor::F32(v) => v.len(),
            Tensor::I32(v) => v.len(),
            Tensor::I8(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// View as f32 (dequantising int8 logits with `scale` when given).
    pub fn to_f32(&self, scale: Option<f64>) -> Vec<f32> {
        match self {
            Tensor::F32(v) => v.to_vec(),
            Tensor::I32(v) => v.iter().map(|&x| x as f32).collect(),
            Tensor::I8(v) => {
                let s = scale.unwrap_or(1.0) as f32;
                v.iter().map(|&x| x as f32 * s).collect()
            }
        }
    }

    /// Index of the maximum element (top-1 class). NaN logits rank below
    /// every real value (and `total_cmp` keeps the order total), so a
    /// model emitting a bad logit yields a wrong class, never a panic in
    /// the serve loop.
    pub fn argmax(&self) -> usize {
        let v = self.to_f32(None);
        let key = |x: f32| if x.is_nan() { f32::NEG_INFINITY } else { x };
        v.iter()
            .enumerate()
            .max_by(|a, b| key(*a.1).total_cmp(&key(*b.1)))
            .map(|(i, _)| i)
            .unwrap_or(0)
    }
}

/// One compiled model variant resident in the engine.
pub struct LoadedModel {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
    /// Weight buffers, uploaded once, passed after the input on every call.
    weights: Vec<xla::PjRtBuffer>,
    /// Host-side literals backing the buffers. The TFRT CPU client uses
    /// zero-copy donation for host uploads, so the literal memory must
    /// outlive the device buffers.
    _weight_literals: Vec<xla::Literal>,
    /// Wall-clock spent compiling + uploading at load time.
    pub load_time_ms: f64,
}

/// The PJRT inference engine. Python never runs here: artifacts are
/// self-contained HLO + weights.
pub struct InferenceEngine {
    client: xla::PjRtClient,
    models: HashMap<String, LoadedModel>,
    /// Interned-route → stem associations learned through the
    /// [`crate::runtime::Inference`] trait's id-addressed `load`.
    route_names: HashMap<super::artifact::ArtifactId, String>,
}

impl InferenceEngine {
    /// Create a CPU-backed engine.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("{e:?}"))?;
        Ok(InferenceEngine { client, models: HashMap::new(), route_names: HashMap::new() })
    }

    /// Associate an interned route id with a stem (id-addressed trait
    /// calls resolve through this; the stem-addressed inherent API is
    /// unaffected).
    pub fn note_route(&mut self, route: super::artifact::ArtifactId, stem: &str) {
        if self.route_names.get(&route).map(String::as_str) != Some(stem) {
            self.route_names.insert(route, stem.to_string());
        }
    }

    /// Stem a route id was loaded under, if any.
    pub fn route_stem(&self, route: super::artifact::ArtifactId) -> Option<&str> {
        self.route_names.get(&route).map(String::as_str)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile an artifact and upload its weights. Idempotent per stem.
    pub fn load(&mut self, meta: &ArtifactMeta) -> Result<()> {
        if self.models.contains_key(&meta.stem) {
            return Ok(());
        }
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&meta.hlo_path)
            .map_err(|e| anyhow!("parsing {}: {e:?}", meta.hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| anyhow!("compile: {e:?}"))?;
        let names: Vec<&str> = meta.weight_keys.iter().map(|s| s.as_str()).collect();
        // NOTE: read through Literal + buffer_from_host_literal rather than
        // PjRtBuffer::read_npz_by_name — the latter forwards ElementType
        // discriminants where the PJRT C API expects PrimitiveType values,
        // producing mis-sized device buffers (crate bug in xla 0.1.6).
        let literals =
            xla::Literal::read_npz_by_name(&meta.weights_path, &(), &names)
                .map_err(|e| anyhow!("weights {}: {e:?}", meta.weights_path.display()))?;
        let weights = literals
            .iter()
            .map(|l| {
                self.client
                    .buffer_from_host_literal(None, l)
                    .map_err(|e| anyhow!("weight upload: {e:?}"))
            })
            .collect::<Result<Vec<_>>>()?;
        self.models.insert(
            meta.stem.clone(),
            LoadedModel {
                meta: meta.clone(),
                exe,
                weights,
                _weight_literals: literals,
                load_time_ms: t0.elapsed().as_secs_f64() * 1000.0,
            },
        );
        Ok(())
    }

    /// Drop a compiled model (the RM unloads designs it rotated away from).
    pub fn unload(&mut self, stem: &str) {
        self.models.remove(stem);
    }

    pub fn is_loaded(&self, stem: &str) -> bool {
        self.models.contains_key(stem)
    }

    pub fn loaded(&self) -> Vec<&LoadedModel> {
        self.models.values().collect()
    }

    /// Run one inference. Validates input shape/dtype against the
    /// manifest; returns the first output tensor (our zoo models return
    /// a 1-tuple of logits).
    pub fn infer(&self, stem: &str, input: &Tensor) -> Result<Tensor> {
        let model = self
            .models
            .get(stem)
            .with_context(|| format!("model {stem} not loaded"))?;
        let meta = &model.meta;
        if input.dtype() != meta.input.dtype {
            return Err(anyhow!(
                "{stem}: input dtype {:?} != manifest {:?}",
                input.dtype(),
                meta.input.dtype
            ));
        }
        if input.len() != meta.input.numel() {
            return Err(anyhow!(
                "{stem}: input numel {} != manifest {}",
                input.len(),
                meta.input.numel()
            ));
        }
        let dims = &meta.input.shape;
        let in_buf = match input {
            Tensor::F32(v) => self.client.buffer_from_host_buffer(v.as_slice(), dims, None),
            Tensor::I32(v) => self.client.buffer_from_host_buffer(v.as_slice(), dims, None),
            Tensor::I8(v) => self.client.buffer_from_host_buffer(v.as_slice(), dims, None),
        }
        .map_err(|e| anyhow!("input upload: {e:?}"))?;

        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + model.weights.len());
        args.push(&in_buf);
        args.extend(model.weights.iter());
        let result = model.exe.execute_b(&args).map_err(|e| anyhow!("execute: {e:?}"))?;
        let literal = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("readback: {e:?}"))?;
        // computations are lowered with return_tuple=True
        let out = literal.to_tuple1().map_err(|e| anyhow!("untuple: {e:?}"))?;
        let spec = &meta.outputs[0];
        let tensor = match spec.dtype {
            DType::F32 => Tensor::F32(out.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?.into()),
            DType::I32 => Tensor::I32(out.to_vec::<i32>().map_err(|e| anyhow!("{e:?}"))?.into()),
            DType::I8 => Tensor::I8(out.to_vec::<i8>().map_err(|e| anyhow!("{e:?}"))?.into()),
        };
        Ok(tensor)
    }

    /// Measure the steady-state latency of a loaded model: `warmup`
    /// throwaway runs then `runs` timed ones. Returns latencies in ms.
    pub fn measure(&self, stem: &str, input: &Tensor, warmup: usize, runs: usize) -> Result<Vec<f64>> {
        for _ in 0..warmup {
            self.infer(stem, input)?;
        }
        let mut out = Vec::with_capacity(runs);
        for _ in 0..runs {
            let t0 = Instant::now();
            self.infer(stem, input)?;
            out.push(t0.elapsed().as_secs_f64() * 1000.0);
        }
        Ok(out)
    }
}

/// Build a zero-filled input tensor matching an artifact's input spec.
pub fn zero_input(meta: &ArtifactMeta) -> Tensor {
    let n = meta.input.numel();
    match meta.input.dtype {
        DType::F32 => Tensor::F32(vec![0.0; n].into()),
        DType::I32 => Tensor::I32(vec![0; n].into()),
        DType::I8 => Tensor::I8(vec![0; n].into()),
    }
}

/// Build a deterministic pseudo-random input for an artifact.
pub fn random_input(meta: &ArtifactMeta, seed: u64) -> Tensor {
    let mut rng = crate::util::Rng::new(seed);
    let n = meta.input.numel();
    match meta.input.dtype {
        DType::F32 => Tensor::F32((0..n).map(|_| rng.normal() as f32).collect::<Vec<_>>().into()),
        DType::I32 => Tensor::I32((0..n).map(|_| rng.below(1024) as i32).collect::<Vec<_>>().into()),
        DType::I8 => Tensor::I8(
            (0..n).map(|_| (rng.below(200) as i32 - 100) as i8).collect::<Vec<_>>().into(),
        ),
    }
}

/// Like [`random_input`], but F32 inputs — the serving-path dtype — fill
/// a buffer leased from `pool` instead of allocating, so the hot path
/// stays allocation-free. Non-F32 inputs fall back to [`random_input`].
pub fn random_input_pooled(meta: &ArtifactMeta, seed: u64, pool: &BufferPool) -> Tensor {
    if meta.input.dtype != DType::F32 {
        return random_input(meta, seed);
    }
    let mut rng = crate::util::Rng::new(seed);
    let n = meta.input.numel();
    Tensor::F32(pool.lease_with(n, |v| {
        for _ in 0..n {
            v.push(rng.normal() as f32);
        }
    }))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basic() {
        assert_eq!(Tensor::F32(vec![0.1, 0.9, 0.5].into()).argmax(), 1);
        assert_eq!(Tensor::I8(vec![-3, 7, 2].into()).argmax(), 1);
        assert_eq!(Tensor::F32(Vec::new().into()).argmax(), 0);
    }

    #[test]
    fn argmax_survives_nan_logits() {
        // NaN compares below every real under total_cmp: a bad output
        // yields some class, never a panic mid-serve.
        let t = Tensor::F32(vec![f32::NAN, 1.0, f32::NAN, 3.0, 2.0].into());
        assert_eq!(t.argmax(), 3);
        // all-NaN still returns an index without panicking
        let all = Tensor::F32(vec![f32::NAN, f32::NAN].into());
        assert!(all.argmax() < 2);
    }

    #[test]
    fn tensor_clone_shares_the_buffer() {
        let t = Tensor::F32(vec![1.0, 2.0].into());
        let u = t.clone();
        let (Tensor::F32(a), Tensor::F32(b)) = (&t, &u) else { unreachable!() };
        assert!(std::ptr::eq(a.as_slice().as_ptr(), b.as_slice().as_ptr()));
    }
}
