//! Tables 7–10: design/switching-policy dumps, solver timing and storage
//! comparisons.

use std::time::Instant;

use crate::config;
use crate::device::profiles;
use crate::moo::baselines;
use crate::moo::rass::{self, EnvState};
use crate::moo::{Problem, Solution};
use crate::util::Rng;
use crate::zoo::Registry;

/// Tables 7/8: the selected designs and the switching policy for a
/// (use case, device) pair, rendered like the paper's rows.
pub fn table7_8_designs(p: &Problem, sol: &Solution) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "Designs for {} on {} (|X'| = {}, solved in {:?}):\n",
        p.name, p.device.name, sol.feasible_count, sol.solve_time
    ));
    for (i, d) in sol.designs.iter().enumerate() {
        out.push_str(&format!("  D[{i}] {}\n", d.describe(p)));
    }
    out.push_str("Switching policy (state -> design):\n");
    let engines = &sol.policy.engines;
    let hdr: Vec<String> = engines
        .iter()
        .map(|e| format!("c_{}", e.name()))
        .chain(std::iter::once("c_m".to_string()))
        .collect();
    out.push_str(&format!("  {}  -> design\n", hdr.join(" ")));
    for (state, didx) in sol.policy.iter_states() {
        let cells: Vec<String> = engines
            .iter()
            .map(|e| if state.is_troubled(*e) { "T".to_string() } else { "F".to_string() })
            .chain(std::iter::once(if state.memory { "T".into() } else { "F".into() }))
            .collect();
        let roles = sol.designs[didx].roles.join(",");
        out.push_str(&format!("  {}   -> d[{didx}] ({roles})\n", cells.join("   ")));
    }
    out
}

/// Table 9: OODIn's (weighted-sum, re-solved per event) solving time in
/// ms over synthetic decision spaces of increasing dimension, versus the
/// RASS policy lookup the RM performs instead. Reports (avg, max) per
/// dimension over `reps` repetitions.
pub struct Table9Row {
    pub dimension: usize,
    pub oodin_avg_ms: f64,
    pub oodin_max_ms: f64,
    pub rass_lookup_avg_ns: f64,
}

pub fn table9_solve_time(dims: &[usize], reps: usize, n_obj: usize) -> Vec<Table9Row> {
    let mut rng = Rng::new(99);
    let mut out = Vec::new();
    // a real policy to time lookups against
    let reg = Registry::paper();
    let p = config::use_case("uc1", &reg, &profiles::galaxy_s20()).unwrap();
    let sol = rass::solve(&p);
    for &dim in dims {
        // synthetic objective matrix, dim x n_obj
        let vectors: Vec<Vec<f64>> = (0..dim)
            .map(|_| (0..n_obj).map(|_| rng.range(0.0, 100.0)).collect())
            .collect();
        let mut times = Vec::with_capacity(reps);
        for _ in 0..reps {
            let t0 = Instant::now();
            std::hint::black_box(baselines::weighted_sum_argmax(&p, &vectors));
            times.push(t0.elapsed().as_secs_f64() * 1000.0);
        }
        // time policy lookups
        let states: Vec<EnvState> = sol.policy.iter_states().map(|(s, _)| s).collect();
        let t0 = Instant::now();
        let n_lookups = 10_000;
        for i in 0..n_lookups {
            std::hint::black_box(sol.policy.design_for(states[i % states.len()]));
        }
        let lookup_ns = t0.elapsed().as_nanos() as f64 / n_lookups as f64;
        out.push(Table9Row {
            dimension: dim,
            oodin_avg_ms: times.iter().sum::<f64>() / times.len() as f64,
            oodin_max_ms: times.iter().copied().fold(f64::MIN, f64::max),
            rass_lookup_avg_ns: lookup_ns,
        });
    }
    out
}

/// Table 10: storage requirements (MB) — CARIn stores only the models of
/// the RASS design set; OODIn must keep every candidate variant resident.
pub struct Table10Row {
    pub use_case: String,
    pub device: String,
    pub carin_mb: f64,
    pub oodin_mb: f64,
    pub reduction: f64,
}

pub fn table10_storage(reg: &Registry) -> Vec<Table10Row> {
    let mut rows = Vec::new();
    for uc in config::USE_CASES {
        for dev in profiles::all() {
            let p = config::use_case(uc, reg, &dev).unwrap();
            let sol = rass::solve(&p);
            // CARIn: unique variants across the design set
            let mut seen = Vec::new();
            let mut carin = 0.0;
            for d in &sol.designs {
                for a in &d.config.assignments {
                    if !seen.contains(&a.variant) {
                        seen.push(a.variant);
                        carin += a.variant.size_bytes(reg);
                    }
                }
            }
            // OODIn: every variant of every task's candidate set
            let mut oodin = 0.0;
            for &task in &p.tasks {
                for v in reg.variants_for_task(task) {
                    oodin += v.size_bytes(reg);
                }
            }
            rows.push(Table10Row {
                use_case: uc.to_string(),
                device: dev.name.to_string(),
                carin_mb: carin / 1e6,
                oodin_mb: oodin / 1e6,
                reduction: oodin / carin.max(1.0),
            });
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table9_oodin_grows_with_dimension() {
        let rows = table9_solve_time(&[500, 5000], 5, 4);
        assert_eq!(rows.len(), 2);
        assert!(rows[1].oodin_avg_ms > rows[0].oodin_avg_ms);
        // RASS lookup is orders of magnitude below OODIn's best case
        for r in &rows {
            assert!(r.rass_lookup_avg_ns / 1e6 < r.oodin_avg_ms / 10.0);
        }
    }

    #[test]
    fn table10_carin_always_smaller() {
        let reg = Registry::paper();
        for r in table10_storage(&reg) {
            assert!(
                r.carin_mb < r.oodin_mb,
                "{}/{}: {} !< {}",
                r.use_case, r.device, r.carin_mb, r.oodin_mb
            );
            assert!(r.reduction > 1.0);
        }
    }

    #[test]
    fn designs_table_renders() {
        let reg = Registry::paper();
        let p = config::use_case("uc1", &reg, &profiles::galaxy_s20()).unwrap();
        let sol = rass::solve(&p);
        let s = table7_8_designs(&p, &sol);
        assert!(s.contains("Switching policy"));
        assert!(s.contains("d0"));
    }
}
