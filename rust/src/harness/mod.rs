//! Evaluation harness: regenerates every table and figure of the paper's
//! §7 as text rows (the same quantities the paper plots), so each bench
//! target maps 1:1 to a paper artefact. See DESIGN.md §5 for the index.

pub mod figures;
pub mod tables;

pub use figures::{figure_multi, figure_single, FigureRow};
pub use tables::{table10_storage, table7_8_designs, table9_solve_time};

/// Render a markdown-ish table.
pub fn render_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for r in rows {
        for (i, c) in r.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(c.len());
            }
        }
    }
    let mut out = String::new();
    let line = |cells: &[String], widths: &[usize]| -> String {
        let mut s = String::from("|");
        for (c, w) in cells.iter().zip(widths) {
            s.push_str(&format!(" {:<w$} |", c, w = w));
        }
        s.push('\n');
        s
    };
    out.push_str(&line(
        &headers.iter().map(|s| s.to_string()).collect::<Vec<_>>(),
        &widths,
    ));
    out.push_str(&line(
        &widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>(),
        &widths,
    ));
    for r in rows {
        out.push_str(&line(r, &widths));
    }
    out
}

#[cfg(test)]
mod tests {
    #[test]
    fn render_aligns() {
        let t = super::render_table(
            &["a", "bbbb"],
            &[vec!["xx".into(), "y".into()]],
        );
        assert!(t.contains("| xx | y    |"));
    }
}
