//! Figures 3–6: optimality of CARIn designs vs the baselines, per device
//! and per available state (single processor for single-DNN problems,
//! processor combination for multi-DNN problems).

use crate::config;
use crate::device::{profiles, Engine};
use crate::moo::baselines::{self, BaselineResult};
use crate::moo::{rass, Problem};
use crate::zoo::Registry;

/// One bar of a figure: (device, state, method) -> optimality.
#[derive(Debug, Clone)]
pub struct FigureRow {
    pub device: String,
    /// Engine-set label, e.g. "CPU" or "CPU+DSP".
    pub state: String,
    pub method: String,
    /// `None` = the method failed to produce a feasible/applicable
    /// solution (the patterned "!"/"N/A" bars of the paper).
    pub optimality: Option<f64>,
    /// True when this state holds the device's initial design d_0.
    pub is_d0: bool,
}

fn engine_label(es: &[Engine]) -> String {
    es.iter().map(|e| e.name()).collect::<Vec<_>>().join("+")
}

fn baseline_row(
    p: &Problem,
    device: &str,
    state: &str,
    r: &BaselineResult,
    is_d0: bool,
) -> FigureRow {
    FigureRow {
        device: device.into(),
        state: state.into(),
        method: r.label.clone(),
        optimality: r.config.as_ref().map(|c| baselines::optimality_of(p, c)),
        is_d0,
    }
}

/// Single-DNN figures (Fig. 3 = UC1, Fig. 4 = UC2): per device, per
/// single-processor state, CARIn vs B-A / B-S / transferred / OODIn.
pub fn figure_single(uc: &str, reg: &Registry) -> Vec<FigureRow> {
    let mut rows = Vec::new();
    let devices = profiles::all();
    for dev in &devices {
        let p = config::use_case(uc, reg, dev).expect("use case");
        let full = rass::solve(&p);
        let d0_engines = full.designs[0].config.engine_set();
        for engine in &dev.engines {
            let state = engine_label(&[*engine]);
            let sub = baselines::restrict_to_engines(&p, &[*engine]);
            let feasible_exists = sub.space.iter().any(|x| sub.feasible(x));
            let is_d0 = d0_engines == vec![*engine];
            // CARIn: best design within this state.
            if feasible_exists {
                let sol = rass::solve(&sub);
                rows.push(FigureRow {
                    device: dev.name.into(),
                    state: state.clone(),
                    method: "CARIn".into(),
                    // measure in the FULL problem's objective stats so
                    // numbers are comparable across states
                    optimality: Some(baselines::optimality_of(&p, &sol.designs[0].config)),
                    is_d0,
                });
            } else {
                rows.push(FigureRow {
                    device: dev.name.into(),
                    state: state.clone(),
                    method: "CARIn".into(),
                    optimality: None,
                    is_d0,
                });
                continue;
            }
            // Baselines, restricted to the same state.
            rows.push(baseline_row(&p, dev.name, &state,
                &baselines::single_architecture(&sub, true), is_d0));
            rows.push(baseline_row(&p, dev.name, &state,
                &baselines::single_architecture(&sub, false), is_d0));
            rows.push(baseline_row(&p, dev.name, &state, &baselines::oodin(&sub), is_d0));
            // Transferred from the other two devices.
            for src_dev in &devices {
                if src_dev.name == dev.name {
                    continue;
                }
                let src = config::use_case(uc, reg, src_dev).expect("use case");
                let src_sub = baselines::restrict_to_engines(&src, &[*engine]);
                let r = if src_sub.space.iter().any(|x| src_sub.feasible(x)) {
                    baselines::transferred(&sub, &src_sub)
                } else {
                    BaselineResult {
                        config: None,
                        solve_time: std::time::Duration::ZERO,
                        label: format!("T_{}", src_dev.name),
                    }
                };
                rows.push(baseline_row(&p, dev.name, &state, &r, is_d0));
            }
        }
    }
    rows
}

/// Multi-DNN figures (Fig. 5 = UC3, Fig. 6 = UC4): per device, per
/// processor *combination*, CARIn vs multi-DNN-unaware / transferred /
/// OODIn. For UC4 only the top-5 combinations per device are reported
/// (as in the paper).
pub fn figure_multi(uc: &str, reg: &Registry, top: Option<usize>) -> Vec<FigureRow> {
    let mut rows = Vec::new();
    let devices = profiles::all();
    for dev in &devices {
        let p = config::use_case(uc, reg, dev).expect("use case");
        let full = rass::solve(&p);
        let d0_engines = full.designs[0].config.engine_set();
        // enumerate engine combinations present in the space
        let mut combos: Vec<Vec<Engine>> = Vec::new();
        for x in &p.space {
            let es = x.engine_set();
            if !combos.contains(&es) {
                combos.push(es);
            }
        }
        // rank combos by CARIn optimality
        let mut scored: Vec<(Vec<Engine>, Option<f64>)> = combos
            .into_iter()
            .map(|es| {
                let sub = baselines::restrict_to_engines(&p, &es);
                let opt = if sub.space.iter().any(|x| sub.feasible(x)) {
                    let sol = rass::solve(&sub);
                    Some(baselines::optimality_of(&p, &sol.designs[0].config))
                } else {
                    None
                };
                (es, opt)
            })
            .collect();
        scored.sort_by(|a, b| {
            b.1.unwrap_or(f64::NEG_INFINITY)
                .partial_cmp(&a.1.unwrap_or(f64::NEG_INFINITY))
                .unwrap()
        });
        if let Some(k) = top {
            scored.truncate(k);
        }
        for (es, carin_opt) in &scored {
            let state = engine_label(es);
            let is_d0 = d0_engines == *es;
            rows.push(FigureRow {
                device: dev.name.into(),
                state: state.clone(),
                method: "CARIn".into(),
                optimality: *carin_opt,
                is_d0,
            });
            let sub = baselines::restrict_to_engines(&p, es);
            rows.push(baseline_row(&p, dev.name, &state,
                &baselines::multi_dnn_unaware(&sub), is_d0));
            rows.push(baseline_row(&p, dev.name, &state, &baselines::oodin(&sub), is_d0));
            for src_dev in &devices {
                if src_dev.name == dev.name {
                    continue;
                }
                let src = config::use_case(uc, reg, src_dev).expect("use case");
                let src_sub = baselines::restrict_to_engines(&src, es);
                let r = if src_sub.space.iter().any(|x| src_sub.feasible(x)) {
                    baselines::transferred(&sub, &src_sub)
                } else {
                    BaselineResult {
                        config: None,
                        solve_time: std::time::Duration::ZERO,
                        label: format!("T_{}", src_dev.name),
                    }
                };
                rows.push(baseline_row(&p, dev.name, &state, &r, is_d0));
            }
        }
    }
    rows
}

/// Aggregate improvement ratios of CARIn over a baseline method across a
/// row set (the §7.1.2 "takeaway" numbers: average and maximum gain).
pub fn gain_over(rows: &[FigureRow], method: &str) -> Option<(f64, f64)> {
    let mut ratios = Vec::new();
    for r in rows.iter().filter(|r| r.method == method) {
        if let Some(base) = r.optimality {
            if let Some(carin) = rows
                .iter()
                .find(|c| {
                    c.method == "CARIn" && c.device == r.device && c.state == r.state
                })
                .and_then(|c| c.optimality)
            {
                if base.is_finite() && carin.is_finite() && base > 0.0 {
                    ratios.push(carin / base);
                }
            }
        }
    }
    if ratios.is_empty() {
        return None;
    }
    let avg = ratios.iter().sum::<f64>() / ratios.len() as f64;
    let max = ratios.iter().copied().fold(f64::MIN, f64::max);
    Some((avg, max))
}

/// Pretty-print figure rows grouped by device/state.
pub fn render(rows: &[FigureRow]) -> String {
    let mut out = String::new();
    let mut keys: Vec<(String, String)> = Vec::new();
    for r in rows {
        let k = (r.device.clone(), r.state.clone());
        if !keys.contains(&k) {
            keys.push(k);
        }
    }
    for (dev, state) in keys {
        let d0 = rows
            .iter()
            .any(|r| r.device == dev && r.state == state && r.is_d0);
        out.push_str(&format!(
            "{dev} / {state}{}\n",
            if d0 { "  [d0]" } else { "" }
        ));
        for r in rows.iter().filter(|r| r.device == dev && r.state == state) {
            match r.optimality {
                Some(o) if o.is_finite() => {
                    out.push_str(&format!("  {:12} {:>8.3}\n", r.method, o))
                }
                Some(_) => out.push_str(&format!("  {:12} {:>8}\n", r.method, "inf")),
                None => out.push_str(&format!("  {:12} {:>8}\n", r.method, "FAIL")),
            }
        }
    }
    out
}
