//! SLO specification parser: a small text DSL so custom applications can
//! be formulated without recompiling (the paper's broad/narrow SLO forms,
//! §4.1):
//!
//! ```text
//! # one directive per line; '#' starts a comment
//! max A            # broad SLO  <max, accuracy>
//! min avg L @1     # broad SLO on task 1 of a multi-DNN app
//! max TP w=2.5     # weighted objective
//! st max L <= 41.67    # narrow SLO <max, latency, 41.67>
//! st p95 E <= 80       # percentile-bounded energy
//! st avg MF <= 90e6
//! ```
//!
//! Metrics: S W A L TP E MF STP NTT F. Statistics: min max avg std pNN.

use anyhow::{anyhow, bail, Result};

use crate::moo::{Constraint, Metric, Objective, Statistic};

/// Parsed SLO specification.
#[derive(Debug, Default)]
pub struct SloSpec {
    pub objectives: Vec<Objective>,
    pub constraints: Vec<Constraint>,
}

fn metric_of(s: &str) -> Result<Metric> {
    Ok(match s.to_ascii_uppercase().as_str() {
        "S" => Metric::Size,
        "W" => Metric::Workload,
        "A" => Metric::Accuracy,
        "L" => Metric::Latency,
        "TP" => Metric::Throughput,
        "E" => Metric::Energy,
        "MF" => Metric::MemFootprint,
        "STP" => Metric::Stp,
        "NTT" => Metric::Ntt,
        "F" => Metric::Fairness,
        other => bail!("unknown metric {other}"),
    })
}

fn stat_of(s: &str) -> Result<Statistic> {
    Ok(match s.to_ascii_lowercase().as_str() {
        "min" => Statistic::Min,
        "max" => Statistic::Max,
        "avg" | "mean" => Statistic::Avg,
        "std" => Statistic::Std,
        p if p.starts_with('p') => {
            let v: f64 = p[1..].parse().map_err(|_| anyhow!("bad percentile {p}"))?;
            Statistic::Percentile(v)
        }
        other => bail!("unknown statistic {other}"),
    })
}

/// Parse a full spec document.
pub fn parse(text: &str) -> Result<SloSpec> {
    let mut spec = SloSpec::default();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap().trim();
        if line.is_empty() {
            continue;
        }
        parse_line(line, &mut spec)
            .map_err(|e| anyhow!("line {}: {e} ({raw:?})", lineno + 1))?;
    }
    if spec.objectives.is_empty() && !spec.constraints.is_empty() {
        // §4.1: when only constraints are given, every inner function h_j
        // also serves as an objective.
        for c in &spec.constraints {
            spec.objectives.push(Objective {
                metric: c.metric,
                stat: c.stat,
                task: c.task,
                weight: 1.0,
            });
        }
    }
    if spec.objectives.is_empty() {
        bail!("spec declares no objectives");
    }
    Ok(spec)
}

fn parse_line(line: &str, spec: &mut SloSpec) -> Result<()> {
    let mut toks: Vec<&str> = line.split_whitespace().collect();
    if toks[0].eq_ignore_ascii_case("st") || toks[0].eq_ignore_ascii_case("s.t.") {
        // constraint: st <stat> <metric> <= <bound> [@task]
        toks.remove(0);
        let (task, rest) = split_task(&toks)?;
        let [stat, metric, op, bound] = rest.as_slice() else {
            bail!("constraint form: st <stat> <metric> <= <bound> [@N]");
        };
        if *op != "<=" && *op != ">=" {
            bail!("constraint operator must be <= or >=");
        }
        let metric = metric_of(metric)?;
        // direction sanity: <= for lower-better, >= for higher-better
        let expected = if metric.higher_is_better() { ">=" } else { "<=" };
        if *op != expected {
            bail!("{} is {}-better; use {expected}", metric.name(),
                  if metric.higher_is_better() { "higher" } else { "lower" });
        }
        spec.constraints.push(Constraint {
            metric,
            stat: stat_of(stat)?,
            task,
            bound: bound.parse().map_err(|_| anyhow!("bad bound {bound}"))?,
        });
        return Ok(());
    }

    // objective: <min|max> [stat] <metric> [@task] [w=K]
    let dir = toks.remove(0);
    if !dir.eq_ignore_ascii_case("min") && !dir.eq_ignore_ascii_case("max") {
        bail!("expected min/max/st, got {dir}");
    }
    let mut weight = 1.0;
    if let Some(pos) = toks.iter().position(|t| t.starts_with("w=")) {
        weight = toks[pos][2..]
            .parse()
            .map_err(|_| anyhow!("bad weight {}", toks[pos]))?;
        toks.remove(pos);
    }
    let (task, rest) = split_task(&toks)?;
    let (stat, metric) = match rest.as_slice() {
        [m] => (Statistic::Avg, metric_of(m)?),
        [s, m] => (stat_of(s)?, metric_of(m)?),
        _ => bail!("objective form: min|max [stat] <metric> [@N] [w=K]"),
    };
    // direction sanity against the metric's canonical direction
    let canonical = if metric.higher_is_better() { "max" } else { "min" };
    if !dir.eq_ignore_ascii_case(canonical) {
        bail!("{} is canonically {canonical}imised", metric.name());
    }
    spec.objectives.push(Objective { metric, stat, task, weight });
    Ok(())
}

fn split_task<'a>(toks: &[&'a str]) -> Result<(Option<usize>, Vec<&'a str>)> {
    let mut task = None;
    let mut rest = Vec::new();
    for t in toks {
        if let Some(n) = t.strip_prefix('@') {
            task = Some(n.parse().map_err(|_| anyhow!("bad task index {t}"))?);
        } else {
            rest.push(*t);
        }
    }
    Ok((task, rest))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_uc1_spec() {
        let spec = parse(
            "# UC1: real-time image classification\n\
             max A\n\
             max TP\n\
             st max L <= 41.67\n",
        )
        .unwrap();
        assert_eq!(spec.objectives.len(), 2);
        assert_eq!(spec.constraints.len(), 1);
        assert_eq!(spec.constraints[0].bound, 41.67);
        assert!(matches!(spec.constraints[0].stat, Statistic::Max));
    }

    #[test]
    fn parses_multi_task_and_weights() {
        let spec = parse(
            "min avg L @0\nmin std L @0 w=0.5\nmax A @1\nst avg L <= 100 @1\n",
        )
        .unwrap();
        assert_eq!(spec.objectives[0].task, Some(0));
        assert_eq!(spec.objectives[1].weight, 0.5);
        assert_eq!(spec.constraints[0].task, Some(1));
    }

    #[test]
    fn percentile_statistic() {
        let spec = parse("max A\nst p95 L <= 20\n").unwrap();
        assert!(matches!(
            spec.constraints[0].stat,
            Statistic::Percentile(p) if (p - 95.0).abs() < 1e-9
        ));
    }

    #[test]
    fn constraints_only_promotes_inner_functions() {
        // §4.1: inner functions become objectives when none are declared
        let spec = parse("st max L <= 10\nst avg MF <= 90e6\n").unwrap();
        assert_eq!(spec.objectives.len(), 2);
    }

    #[test]
    fn rejects_wrong_direction() {
        assert!(parse("min A\n").is_err()); // accuracy is higher-better
        assert!(parse("max L\n").is_err()); // latency is lower-better
        assert!(parse("max A\nst max L >= 10\n").is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("maximize the vibes\n").is_err());
        assert!(parse("max Q\n").is_err());
        assert!(parse("st max L <= ten\n").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn full_problem_from_spec_solves() {
        let reg = crate::zoo::Registry::paper();
        let dev = crate::device::profiles::pixel7();
        let spec = parse("max A\nmin avg E\nst max L <= 41.67\n").unwrap();
        let p = crate::moo::space::build_problem(
            "custom",
            vec![crate::zoo::Task::ImageCls],
            dev,
            reg,
            spec.objectives,
            spec.constraints,
            7,
        );
        let sol = crate::moo::rass::solve(&p);
        assert!(!sol.designs.is_empty());
    }
}
