"""L2 layer-level tests: Ctx scheme dispatch, conv-as-im2col vs lax.conv,
attention, calibration recording."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import nn
from compile.nn import Ctx


def _params(spec, seed=0):
    return nn.init_params(spec, seed)


class TestDense:
    spec = {"d": (32, 16), "d/b": (16,)}

    def _x(self):
        return jnp.asarray(np.random.default_rng(1).standard_normal((8, 32)), jnp.float32)

    def test_fp32(self):
        p = _params(self.spec)
        out = Ctx(p, "fp32").dense(self._x(), "d")
        ref = np.asarray(self._x()) @ p["d"] + p["d/b"]
        np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-5, atol=1e-5)

    def test_fp16_quantisation_error_bounded(self):
        p = _params(self.spec)
        out32 = Ctx(p, "fp32").dense(self._x(), "d")
        tp16 = nn.transform_params(p, {"d": "dense"}, "fp16")
        out16 = Ctx(tp16, "fp16").dense(self._x(), "d")
        err = np.abs(np.asarray(out32) - np.asarray(out16))
        assert err.max() < 0.05  # fp16 weight rounding only
        assert err.max() > 0.0  # but it *is* a different graph

    @pytest.mark.parametrize("scheme", ["dr8", "fx8", "ffx8"])
    def test_int8_schemes_close(self, scheme):
        p = _params(self.spec)
        x = self._x()
        calib = {"d": float(jnp.max(jnp.abs(x)))}
        kinds = {"d": "dense"}
        out32 = np.asarray(Ctx(nn.transform_params(p, kinds, "fp32"), "fp32").dense(x, "d"))
        tp = nn.transform_params(p, kinds, scheme)
        outq = np.asarray(Ctx(tp, scheme, calib=calib).dense(x, "d"))
        rel = np.mean(np.abs(outq - out32)) / np.mean(np.abs(out32))
        assert rel < 0.05, rel

    def test_record_mode_captures_absmax(self):
        p = _params(self.spec)
        rec = {}
        x = self._x()
        Ctx(p, "ffx8", record=rec).dense(x, "d")
        assert rec["d"] == pytest.approx(float(jnp.max(jnp.abs(x))))

    def test_record_mode_takes_running_max(self):
        p = _params(self.spec)
        rec = {"d": 1e9}
        Ctx(p, "fp32", record=rec).dense(self._x(), "d")
        assert rec["d"] == 1e9

    def test_activations(self):
        p = _params(self.spec)
        out = Ctx(p, "fp32").dense(self._x(), "d", act="relu6")
        o = np.asarray(out)
        assert o.min() >= 0.0 and o.max() <= 6.0


class TestConv:
    def test_conv2d_matches_lax_conv(self):
        rng = np.random.default_rng(2)
        p = {"c": rng.standard_normal((3, 3, 4, 8)).astype(np.float32) * 0.1,
             "c/b": np.zeros((8,), np.float32)}
        x = jnp.asarray(rng.standard_normal((2, 9, 9, 4)), jnp.float32)
        tp = nn.transform_params(p, {"c": "dense"}, "fp32")
        for stride in (1, 2):
            got = Ctx(tp, "fp32").conv2d(x, "c", stride=stride)
            ref = jax.lax.conv_general_dilated(
                x, jnp.asarray(p["c"]), (stride, stride),
                padding=[(1, 1), (1, 1)],
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
            )
            np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                       rtol=1e-4, atol=1e-4)

    def test_conv2d_even_kernel_shape(self):
        rng = np.random.default_rng(3)
        p = {"c": rng.standard_normal((1, 1, 4, 6)).astype(np.float32),
             "c/b": np.zeros((6,), np.float32)}
        x = jnp.asarray(rng.standard_normal((1, 5, 5, 4)), jnp.float32)
        tp = nn.transform_params(p, {"c": "dense"}, "fp32")
        out = Ctx(tp, "fp32").conv2d(x, "c")
        assert out.shape == (1, 5, 5, 6)

    def test_depthwise_shape_and_grouping(self):
        rng = np.random.default_rng(4)
        c = 6
        p = {"d": rng.standard_normal((3, 3, c, 1)).astype(np.float32),
             "d/b": np.zeros((c,), np.float32)}
        x = np.zeros((1, 8, 8, c), np.float32)
        x[0, :, :, 2] = 1.0  # only channel 2 lit
        tp = nn.transform_params(p, {"d": "dw"}, "fp32")
        out = np.asarray(Ctx(tp, "fp32").depthwise(jnp.asarray(x), "d"))
        assert out.shape == (1, 8, 8, c)
        # depthwise: output channel j depends only on input channel j
        for j in range(c):
            if j != 2:
                np.testing.assert_allclose(out[..., j], 0.0, atol=1e-6)


class TestEmbed:
    def test_embed_fp32_is_table_lookup(self):
        rng = np.random.default_rng(5)
        p = {"e": rng.standard_normal((10, 4)).astype(np.float32)}
        ids = jnp.asarray(np.array([3, 1, 3], np.int32))
        tp = nn.transform_params(p, {"e": "embed"}, "fp32")
        out = np.asarray(Ctx(tp, "fp32").embed(ids, "e"))
        np.testing.assert_allclose(out, p["e"][[3, 1, 3]])

    def test_embed_int8_close(self):
        rng = np.random.default_rng(6)
        p = {"e": rng.standard_normal((100, 32)).astype(np.float32)}
        ids = jnp.asarray(np.arange(50, dtype=np.int32))
        ref = np.asarray(Ctx(nn.transform_params(p, {"e": "embed"}, "fp32"), "fp32").embed(ids, "e"))
        got = np.asarray(Ctx(nn.transform_params(p, {"e": "embed"}, "dr8"), "dr8").embed(ids, "e"))
        assert np.mean(np.abs(got - ref)) < 0.02


class TestAttention:
    def test_shapes_and_softmax_rows(self):
        h, s, heads = 32, 12, 4
        spec = {}
        for nm in ("q", "k", "v", "o"):
            spec[f"a/{nm}"] = (h, h)
            spec[f"a/{nm}/b"] = (h,)
        p = _params(spec, 7)
        x = jnp.asarray(np.random.default_rng(8).standard_normal((s, h)), jnp.float32)
        out = nn.attention(Ctx(p, "fp32"), x, "a", heads)
        assert out.shape == (s, h)
        assert np.all(np.isfinite(np.asarray(out)))


def test_affine():
    p = {"n/g": np.full((4,), 2.0, np.float32), "n/bb": np.ones((4,), np.float32)}
    x = jnp.ones((3, 4))
    out = np.asarray(Ctx(p, "fp32").affine(x, "n"))
    np.testing.assert_allclose(out, 3.0)


def test_init_params_deterministic():
    spec = {"w": (8, 8), "w/b": (8,)}
    a, b = nn.init_params(spec, 42), nn.init_params(spec, 42)
    np.testing.assert_array_equal(a["w"], b["w"])
    c = nn.init_params(spec, 43)
    assert not np.array_equal(a["w"], c["w"])
    np.testing.assert_array_equal(a["w/b"], 0.0)
