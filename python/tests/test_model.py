"""Model-zoo tests: shapes, scheme agreement, determinism, calibration."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import nn


def _input_for(md, scheme, seed=0):
    ex = md.example_input()
    rng = np.random.default_rng(seed)
    if ex.dtype == np.int32:
        return rng.integers(0, 1024, ex.shape).astype(np.int32)
    if scheme == "ffx8":
        return rng.integers(-100, 100, ex.shape).astype(np.int8)
    return rng.standard_normal(ex.shape).astype(np.float32)


def test_zoo_complete():
    names = {m.name for m in M.ZOO}
    assert len(names) == len(M.ZOO), "duplicate model names"
    tasks = {m.task for m in M.ZOO}
    assert tasks == {"uc1", "uc2", "uc3", "uc4"}


@pytest.mark.parametrize("name", ["cnn_s", "bert_s", "yamnet_lite", "face_gender"])
def test_output_shapes_all_schemes(name):
    md = M.get(name)
    calib = md.calibrate(num_batches=1) if any(
        s in md.schemes for s in ("fx8", "ffx8")) else None
    shapes = set()
    for scheme in md.schemes:
        run, _, _ = md.fn(scheme, calib=calib)
        out = run(jnp.asarray(_input_for(md, scheme)))
        assert len(out) == 1
        shapes.add(out[0].shape)
        if scheme == "ffx8":
            assert out[0].dtype == jnp.int8
        else:
            assert out[0].dtype == jnp.float32
    assert len(shapes) == 1, "schemes must agree on logits shape"


@pytest.mark.parametrize("name,classes", [("cnn_s", 100), ("bert_s", 6),
                                          ("scene_s", 67), ("yamnet_lite", 521),
                                          ("face_eth", 5)])
def test_class_counts(name, classes):
    md = M.get(name)
    run, _, _ = md.fn("fp32")
    out = run(jnp.asarray(_input_for(md, "fp32")))
    assert out[0].shape[-1] == classes


def test_face_models_batch4():
    for name in ("face_gender", "face_age", "face_eth"):
        md = M.get(name)
        assert md.example_input().shape[0] == 4


@pytest.mark.parametrize("name", ["cnn_s", "bert_s"])
def test_quantised_schemes_track_fp32(name):
    """Top-1 agreement between fp32 and each quantised variant: quantised
    logits must correlate strongly (the accuracy-preservation premise of
    Table 2-5)."""
    md = M.get(name)
    calib = md.calibrate(num_batches=2)
    ref_run, _, _ = md.fn("fp32")
    for scheme in ("fp16", "dr8", "fx8"):
        if scheme not in md.schemes:
            continue
        run, _, _ = md.fn(scheme, calib=calib)
        agree = 0
        for seed in range(5):
            x = _input_for(md, scheme, seed)
            ref = np.asarray(ref_run(jnp.asarray(x))[0])
            got = np.asarray(run(jnp.asarray(x))[0])
            agree += int(np.argmax(ref) == np.argmax(got))
        assert agree >= 4, f"{name}/{scheme}: top-1 agreement {agree}/5"


def test_ffx8_logits_order_preserved():
    md = M.get("cnn_s")
    calib = md.calibrate(num_batches=2)
    ref_run, _, _ = md.fn("fp32")
    run, _, in_scale = md.fn("ffx8", calib=calib)
    agree = 0
    for seed in range(5):
        xf = _input_for(md, "fp32", seed)
        ref = np.asarray(ref_run(jnp.asarray(xf))[0])
        # quantise the same input with the baked-in input scale
        xq = np.clip(np.round(xf / in_scale), -127, 127).astype(np.int8)
        got = np.asarray(run(jnp.asarray(xq))[0])
        agree += int(np.argmax(ref) == np.argmax(got))
    assert agree >= 4


def test_model_deterministic():
    md = M.get("cnn_s")
    run, _, _ = md.fn("fp32")
    x = jnp.asarray(_input_for(md, "fp32", 9))
    a = np.asarray(run(x)[0])
    b = np.asarray(run(x)[0])
    np.testing.assert_array_equal(a, b)


def test_calibration_nonempty_and_positive():
    md = M.get("cnn_s")
    calib, kinds = md.calibrate(num_batches=1)
    assert calib
    assert all(v > 0 for v in calib.values())
    assert set(kinds.values()) <= {"dense", "dw", "embed", "aux"}


def test_params_and_flops_ordering():
    """Bigger family members must cost more (drives the MOO trade-off)."""
    for fam in (("cnn_s", "cnn_m", "cnn_l"), ("bert_s", "bert_m", "bert_l"),
                ("scene_s", "scene_m", "scene_l")):
        sizes = [M.get(n).num_params for n in fam]
        flops = [M.get(n).flops for n in fam]
        assert sizes == sorted(sizes)
        assert flops == sorted(flops)


def test_bytes_per_param_table1():
    assert nn.BYTES_PER_PARAM["fp32"] / nn.BYTES_PER_PARAM["fp16"] == 2.0
    for s in ("dr8", "fx8", "ffx8"):
        assert nn.BYTES_PER_PARAM["fp32"] / nn.BYTES_PER_PARAM[s] == 4.0
