"""AOT export tests: HLO text integrity (no elided constants), manifest
schema, and jit-vs-eager numeric agreement for an exported model."""

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import numpy as np
import pytest

from compile import aot, model as M


@pytest.fixture(scope="module")
def export_cnn_s(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    md = M.get("cnn_s")
    calib = md.calibrate(num_batches=1)
    entries = [
        aot.export_one(md, scheme, str(out), calib, check=True)
        for scheme in ("fp32", "ffx8")
    ]
    return out, entries


def test_hlo_has_no_elided_constants(export_cnn_s):
    out, entries = export_cnn_s
    for e in entries:
        text = (out / e["file"]).read_text()
        assert "constant({...})" not in text, "weights were elided from HLO text"
        assert text.startswith("HloModule")


def test_manifest_entry_schema(export_cnn_s):
    out, entries = export_cnn_s
    e = entries[0]
    for key in ("file", "weights", "weight_keys", "model", "task", "scheme",
                "input", "outputs", "params", "flops", "weight_bytes",
                "hlo_bytes"):
        assert key in e
    assert e["input"]["shape"] == [1, 96, 96, 3]
    assert e["weight_bytes"] == e["params"] * 4  # fp32
    assert (out / e["weights"]).exists()


def test_weight_keys_sorted_and_match_npz(export_cnn_s):
    out, entries = export_cnn_s
    for e in entries:
        assert e["weight_keys"] == sorted(e["weight_keys"])
        npz = np.load(out / e["weights"])
        assert sorted(npz.files) == e["weight_keys"]


def test_ffx8_manifest_int8_io(export_cnn_s):
    _, entries = export_cnn_s
    e = next(x for x in entries if x["scheme"] == "ffx8")
    assert e["input"]["dtype"] == "int8"
    assert e["outputs"][0]["dtype"] == "int8"
    assert e["input_scale"] is not None and e["input_scale"] > 0
    # int8 weights + small f32 scales/biases: ~4x reduction vs fp32
    fp32 = next(x for x in entries if x["scheme"] == "fp32")
    assert e["weight_bytes"] < fp32["weight_bytes"] / 2.5


def test_entry_layout_declared(export_cnn_s):
    out, entries = export_cnn_s
    text = (out / entries[0]["file"]).read_text()
    assert "entry_computation_layout" in text


def test_jit_matches_eager():
    md = M.get("cnn_s")
    run, example, _ = md.fn("fp32")
    x = np.random.default_rng(0).standard_normal(example.shape).astype(np.float32)
    eager = np.asarray(run(x)[0])
    jitted = np.asarray(jax.jit(run)(x)[0])
    np.testing.assert_allclose(jitted, eager, rtol=1e-4, atol=1e-4)


def test_repo_manifest_consistent_if_built():
    """If `make artifacts` has run, the manifest must match the files."""
    art = Path(__file__).resolve().parents[2] / "artifacts"
    man = art / "manifest.json"
    if not man.exists():
        pytest.skip("artifacts not built")
    entries = json.loads(man.read_text())
    assert entries, "empty manifest"
    for e in entries:
        f = art / e["file"]
        assert f.exists(), f"missing artifact {e['file']}"
        assert f.stat().st_size == e["hlo_bytes"]
        assert e["scheme"] in ("fp32", "fp16", "dr8", "fx8", "ffx8")
