"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes and dtypes; the integer path must match the
oracle exactly, the float path to tight tolerance.
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import compile.kernels.qmatmul as K
from compile.kernels import ref as R

dims = st.integers(min_value=1, max_value=200)


@settings(max_examples=25, deadline=None)
@given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**31 - 1))
def test_matmul_f32_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((m, k)).astype(np.float32)
    w = rng.standard_normal((k, n)).astype(np.float32)
    got = np.asarray(K.matmul_f32(jnp.asarray(x), jnp.asarray(w)))
    ref = np.asarray(R.matmul_f32_ref(jnp.asarray(x), jnp.asarray(w)))
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**31 - 1))
def test_matmul_int8_exact(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = rng.integers(-127, 128, (m, k)).astype(np.int8)
    w = rng.integers(-127, 128, (k, n)).astype(np.int8)
    got = np.asarray(K.matmul_int8(jnp.asarray(x), jnp.asarray(w)))
    ref = x.astype(np.int32) @ w.astype(np.int32)
    assert got.dtype == np.int32
    np.testing.assert_array_equal(got, ref)


@settings(max_examples=15, deadline=None)
@given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**31 - 1))
def test_qmatmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = rng.integers(-127, 128, (m, k)).astype(np.int8)
    w = rng.integers(-127, 128, (k, n)).astype(np.int8)
    xs = np.float32(rng.uniform(0.001, 0.1))
    ws = rng.uniform(0.001, 0.1, n).astype(np.float32)
    got = np.asarray(K.qmatmul(jnp.asarray(x), jnp.asarray(w), xs, jnp.asarray(ws)))
    ref = np.asarray(R.qmatmul_ref(jnp.asarray(x), jnp.asarray(w), xs, ws))
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("m,k,n", [(1, 1, 1), (1, 1536, 1), (128, 128, 128),
                                   (129, 64, 257), (7, 3, 5), (200, 200, 200)])
def test_matmul_edge_shapes(m, k, n):
    rng = np.random.default_rng(0)
    x = rng.standard_normal((m, k)).astype(np.float32)
    w = rng.standard_normal((k, n)).astype(np.float32)
    got = np.asarray(K.matmul_f32(jnp.asarray(x), jnp.asarray(w)))
    np.testing.assert_allclose(got, x @ w, rtol=1e-5, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(k=dims, n=dims, seed=st.integers(0, 2**31 - 1))
def test_quantize_weights_roundtrip(k, n, seed):
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((k, n)).astype(np.float32)
    w_q, scale = K.quantize_weights(jnp.asarray(w))
    w_q, scale = np.asarray(w_q), np.asarray(scale)
    assert w_q.dtype == np.int8 and scale.shape == (n,)
    assert np.all(np.abs(w_q) <= 127)
    # dequantised weights within half an lsb per channel
    err = np.abs(w_q.astype(np.float32) * scale[None, :] - w)
    assert np.all(err <= scale[None, :] * 0.5 + 1e-6)
    # matches the oracle exactly
    wq_r, s_r = R.quantize_weights_ref(jnp.asarray(w))
    np.testing.assert_array_equal(w_q, np.asarray(wq_r))
    np.testing.assert_allclose(scale, np.asarray(s_r), rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(m=dims, k=dims, seed=st.integers(0, 2**31 - 1))
def test_quantize_dynamic_bounds(m, k, seed):
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal((m, k)) * rng.uniform(0.01, 100)).astype(np.float32)
    x_q, scale = K.quantize_dynamic(jnp.asarray(x))
    x_q, scale = np.asarray(x_q), float(scale)
    assert x_q.dtype == np.int8
    assert np.max(np.abs(x_q)) <= 127
    np.testing.assert_allclose(
        x_q.astype(np.float32) * scale, x, atol=scale * 0.5 + 1e-6
    )


def test_dense_dr8_close_to_f32():
    rng = np.random.default_rng(3)
    x = rng.standard_normal((16, 64)).astype(np.float32)
    w = rng.standard_normal((64, 32)).astype(np.float32)
    b = rng.standard_normal((32,)).astype(np.float32)
    w_q, w_s = K.quantize_weights(jnp.asarray(w))
    got = np.asarray(K.dense_dr8(jnp.asarray(x), w_q, w_s, jnp.asarray(b)))
    ref = x @ w + b
    # int8 x int8 quantisation noise: relative error ~1%
    assert np.mean(np.abs(got - ref)) / np.mean(np.abs(ref)) < 0.05


def test_dense_fx8_close_to_f32():
    rng = np.random.default_rng(4)
    x = rng.standard_normal((16, 64)).astype(np.float32)
    w = rng.standard_normal((64, 32)).astype(np.float32)
    w_q, w_s = K.quantize_weights(jnp.asarray(w))
    x_scale = float(np.abs(x).max()) / 127.0
    got = np.asarray(K.dense_fx8(jnp.asarray(x), w_q, w_s, x_scale))
    ref = x @ w
    assert np.mean(np.abs(got - ref)) / np.mean(np.abs(ref)) < 0.05


def test_quantize_static_saturates():
    x = jnp.asarray(np.array([[1000.0, -1000.0, 0.0, 0.5]], np.float32))
    x_q = np.asarray(K.quantize_static(x, 1.0))
    np.testing.assert_array_equal(x_q[0], np.array([127, -127, 0, 0], np.int8))


@settings(max_examples=15, deadline=None)
@given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**31 - 1))
def test_qmatmul_fused_matches_unfused(m, k, n, seed):
    """Perf-pass L1 iteration: the fused dequant-epilogue kernel must be
    numerically identical to the unfused (matmul_int8 + XLA epilogue)."""
    rng = np.random.default_rng(seed)
    x = rng.integers(-127, 128, (m, k)).astype(np.int8)
    w = rng.integers(-127, 128, (k, n)).astype(np.int8)
    xs = np.float32(rng.uniform(0.001, 0.1))
    ws = rng.uniform(0.001, 0.1, n).astype(np.float32)
    fused = np.asarray(K.qmatmul_fused(jnp.asarray(x), jnp.asarray(w), xs, jnp.asarray(ws)))
    ref = np.asarray(R.qmatmul_ref(jnp.asarray(x), jnp.asarray(w), xs, ws))
    np.testing.assert_allclose(fused, ref, rtol=1e-5, atol=1e-6)
