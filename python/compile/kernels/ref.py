"""Pure-jnp correctness oracles for the L1 Pallas kernels.

Every kernel in ``qmatmul.py`` must agree with its oracle here to within
float tolerance (exactly, for the integer path). The pytest suite sweeps
shapes and dtypes with hypothesis and asserts allclose.
"""

from __future__ import annotations

import jax.numpy as jnp


def matmul_f32_ref(x, w):
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))


def matmul_int8_ref(x_q, w_q):
    return jnp.dot(x_q.astype(jnp.int32), w_q.astype(jnp.int32))


def qmatmul_ref(x_q, w_q, x_scale, w_scale):
    acc = matmul_int8_ref(x_q, w_q).astype(jnp.float32)
    return acc * x_scale * jnp.asarray(w_scale).reshape(1, -1)


def quantize_weights_ref(w, axis: int = -1):
    w = jnp.asarray(w, jnp.float32)
    axes = tuple(i for i in range(w.ndim) if i != axis % w.ndim)
    amax = jnp.max(jnp.abs(w), axis=axes)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    shape = [1] * w.ndim
    shape[axis % w.ndim] = -1
    w_q = jnp.clip(jnp.round(w / scale.reshape(shape)), -127, 127).astype(jnp.int8)
    return w_q, scale.astype(jnp.float32)
