# NOTE: the `qmatmul` *function* is intentionally not re-exported here —
# binding it at package level would shadow the `kernels.qmatmul` submodule
# (tests import the module for direct kernel access).
from .qmatmul import (  # noqa: F401
    dense_dr8,
    dense_f32,
    dense_fx8,
    matmul_f32,
    matmul_int8,
    quantize_dynamic,
    quantize_static,
    quantize_weights,
)
