"""L1 Pallas kernels: the inference hot-spot of every model in the zoo.

Two tiled matmul kernels back all dense layers and all im2col-lowered
convolutions in the CARIn model zoo:

* ``matmul_f32``   — f32 x f32 -> f32 (FP32 / FP16-fallback paths)
* ``matmul_int8``  — int8 x int8 -> int32 (DR8 / FX8 / FFX8 paths)

Hardware adaptation (paper -> TPU, see DESIGN.md §Hardware-Adaptation):
the paper's quantised TFLite kernels target ARM NEON / Hexagon HVX; here
the same insight — int8 halves/quarters memory traffic and unlocks the
integer engine — is expressed as MXU-friendly tiles: blocks of
(bm, K) x (K, bn) staged through VMEM via BlockSpec, accumulating in
i32/f32. Kernels are lowered with ``interpret=True``: the CPU PJRT client
cannot execute Mosaic custom-calls, and correctness is what the interpret
path validates (TPU perf is estimated analytically in DESIGN.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. 128 matches the MXU systolic-array edge; tiles are
# shrunk to the (padded) problem size for the small end of the zoo.
BLOCK_M = 128
BLOCK_N = 128


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _matmul_kernel(x_ref, w_ref, o_ref, *, acc_dtype):
    """One (bm, K) x (K, bn) tile. K is kept whole-in-VMEM: every model in
    the zoo has K <= 1536, so x-tile + w-tile + acc fit comfortably in the
    ~16 MB VMEM budget (see DESIGN.md §Perf for the footprint table)."""
    o_ref[...] = jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=acc_dtype
    ).astype(o_ref.dtype)


def _pallas_matmul(x, w, *, out_dtype, acc_dtype, block_m=BLOCK_M, block_n=BLOCK_N):
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"

    bm = min(block_m, _ceil_to(m, 8))
    bn = min(block_n, _ceil_to(n, 8))
    mp, np_ = _ceil_to(m, bm), _ceil_to(n, bn)
    if mp != m or np_ != n:
        x = jnp.pad(x, ((0, mp - m), (0, 0)))
        w = jnp.pad(w, ((0, 0), (0, np_ - n)))

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, acc_dtype=acc_dtype),
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        interpret=True,
    )(x, w)
    return out[:m, :n]


def matmul_f32(x: jax.Array, w: jax.Array) -> jax.Array:
    """f32 (M, K) @ (K, N) -> f32 (M, N) through the Pallas tile kernel."""
    return _pallas_matmul(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        out_dtype=jnp.float32,
        acc_dtype=jnp.float32,
    )


def matmul_int8(x_q: jax.Array, w_q: jax.Array) -> jax.Array:
    """int8 (M, K) @ (K, N) -> int32 (M, N). Raw integer accumulation;
    dequantisation is applied by the caller (XLA fuses the elementwise
    epilogue into the surrounding graph)."""
    assert x_q.dtype == jnp.int8 and w_q.dtype == jnp.int8
    return _pallas_matmul(x_q, w_q, out_dtype=jnp.int32, acc_dtype=jnp.int32)


def qmatmul(
    x_q: jax.Array,
    w_q: jax.Array,
    x_scale: jax.Array,
    w_scale: jax.Array,
) -> jax.Array:
    """Quantised matmul with dequant epilogue.

    x_q      : int8 (M, K) activations
    w_q      : int8 (K, N) weights
    x_scale  : f32 scalar or (M, 1) per-row activation scale
    w_scale  : f32 (N,)   per-channel weight scale
    returns  : f32 (M, N) = (x_q @ w_q) * x_scale * w_scale
    """
    acc = matmul_int8(x_q, w_q)
    return acc.astype(jnp.float32) * x_scale * w_scale.reshape(1, -1)


def _qmatmul_fused_kernel(x_ref, w_ref, xs_ref, ws_ref, o_ref):
    """Perf-pass L1 iteration (EXPERIMENTS.md §Perf): the int32
    accumulator never leaves VMEM — the dequant epilogue runs on the tile
    before the f32 result is written, saving the M*N*4B int32 round trip
    to HBM that the unfused pair (matmul_int8 + XLA elementwise) pays."""
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.int32)
    o_ref[...] = acc.astype(jnp.float32) * xs_ref[0] * ws_ref[...].reshape(1, -1)


def qmatmul_fused(
    x_q: jax.Array,
    w_q: jax.Array,
    x_scale: jax.Array,
    w_scale: jax.Array,
) -> jax.Array:
    """Fused variant of [`qmatmul`]: int8 x int8 -> i32 accumulate ->
    dequant, all inside one Pallas tile. Numerically identical."""
    assert x_q.dtype == jnp.int8 and w_q.dtype == jnp.int8
    m, k = x_q.shape
    k2, n = w_q.shape
    assert k == k2
    bm = min(BLOCK_M, _ceil_to(m, 8))
    bn = min(BLOCK_N, _ceil_to(n, 8))
    mp, np_ = _ceil_to(m, bm), _ceil_to(n, bn)
    if mp != m or np_ != n:
        x_q = jnp.pad(x_q, ((0, mp - m), (0, 0)))
        w_q = jnp.pad(w_q, ((0, 0), (0, np_ - n)))
        w_scale = jnp.pad(w_scale, (0, np_ - n))
    xs = jnp.reshape(jnp.asarray(x_scale, jnp.float32), (1,))
    out = pl.pallas_call(
        _qmatmul_fused_kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(x_q, w_q, xs, w_scale)
    return out[:m, :n]


# ---------------------------------------------------------------------------
# Quantisation helpers (TFLite-converter semantics, symmetric int8).
# ---------------------------------------------------------------------------

def quantize_weights(w, axis: int = -1):
    """Symmetric per-channel int8 quantisation of a weight matrix.

    Returns (w_q int8, scale f32 per output channel).
    """
    w = jnp.asarray(w, jnp.float32)
    amax = jnp.max(jnp.abs(w), axis=tuple(i for i in range(w.ndim) if i != axis % w.ndim))
    scale = jnp.maximum(amax, 1e-8) / 127.0
    shape = [1] * w.ndim
    shape[axis % w.ndim] = -1
    w_q = jnp.clip(jnp.round(w / scale.reshape(shape)), -127, 127).astype(jnp.int8)
    return w_q, scale.astype(jnp.float32)


def quantize_dynamic(x):
    """TFLite DR8 dynamic-range activation quantisation: per-tensor scale
    computed at inference time. Returns (x_q int8, scale f32 scalar)."""
    x = jnp.asarray(x, jnp.float32)
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-8) / 127.0
    x_q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return x_q, scale


def quantize_static(x, scale: float):
    """FX8/FFX8 static activation quantisation with a calibration-time
    scale baked into the graph."""
    x_q = jnp.clip(jnp.round(jnp.asarray(x, jnp.float32) / scale), -127, 127)
    return x_q.astype(jnp.int8)


def dense_f32(x, w, b=None):
    """FP32/FP16 dense layer on the Pallas f32 kernel."""
    out = matmul_f32(x, w)
    if b is not None:
        out = out + b
    return out


def dense_dr8(x, w_q, w_scale, b=None):
    """DR8 dense layer: dynamic activation quant + int8 kernel + dequant."""
    x_q, x_scale = quantize_dynamic(x)
    out = qmatmul(x_q, w_q, x_scale, w_scale)
    if b is not None:
        out = out + b
    return out


def dense_fx8(x, w_q, w_scale, x_scale: float, b=None):
    """FX8/FFX8 dense layer: static activation quant + the fused int8
    kernel (dequant epilogue in-tile — see qmatmul_fused)."""
    x_q = quantize_static(x, x_scale)
    out = qmatmul_fused(x_q, w_q, jnp.float32(x_scale), w_scale)
    if b is not None:
        out = out + b
    return out
