"""L2 model zoo: the executable DNNs served by the rust coordinator.

Compact JAX re-implementations of the paper's four model families
(§6.2, Tables 2-5), scaled to laptop-class artifact sizes (documented
substitution — DESIGN.md §6):

* ``cnn_*``    — MobileNetV2-style inverted-residual image classifiers
                 (UC1 image classification, UC3 scene classification).
* ``bert_*``   — BERT-style transformer text classifiers with the paper's
                 mobile-friendly tweaks (ReLU instead of GELU, affine
                 instead of LayerNorm) (UC2 emotion classification).
* ``yamnet_lite`` — audio event classifier: fixed framing front-end +
                 depthwise-separable conv stack (UC3 audio).
* ``face_*``   — MobileNetV2-backbone facial-attribute heads, batch 4
                 (UC4 gender / age / ethnicity).

Each model is a pure function of its input with weights baked in as
constants, built per quantisation scheme (Table 1) via ``nn.Ctx``, so a
single (model, scheme) pair lowers to one self-contained HLO module.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import nn
from .nn import Ctx


# ---------------------------------------------------------------------------
# Architecture builders. Each returns (param_spec, forward, example_input,
# flops). forward(ctx, x) -> logits.
# ---------------------------------------------------------------------------


def _cnn_spec(hw: int, widths: List[int], num_classes: int):
    """MobileNetV2-style: stem conv s2, one inverted-residual block per
    width entry (expand 2x -> depthwise s2 -> project), GAP, classifier."""
    spec: Dict[str, tuple] = {}
    flops = 0
    c_in = 3
    h = hw // 2
    spec["stem"] = (3, 3, 3, widths[0])
    spec["stem/b"] = (widths[0],)
    flops += 2 * h * h * 3 * 3 * 3 * widths[0]
    c_in = widths[0]
    for i, c_out in enumerate(widths):
        e = c_in * 2
        spec[f"blk{i}/exp"] = (1, 1, c_in, e)
        spec[f"blk{i}/exp/b"] = (e,)
        spec[f"blk{i}/dw"] = (3, 3, e, 1)
        spec[f"blk{i}/dw/b"] = (e,)
        spec[f"blk{i}/proj"] = (1, 1, e, c_out)
        spec[f"blk{i}/proj/b"] = (c_out,)
        flops += 2 * h * h * c_in * e  # expand
        h2 = h // 2
        flops += 2 * h2 * h2 * 9 * e  # depthwise (s2)
        flops += 2 * h2 * h2 * e * c_out  # project
        h = h2
        c_in = c_out
    spec["head"] = (c_in, num_classes)
    spec["head/b"] = (num_classes,)
    flops += 2 * c_in * num_classes

    def forward(ctx: Ctx, x):
        x = ctx.conv2d(x, "stem", stride=2, act="relu6")
        for i in range(len(widths)):
            y = ctx.conv2d(x, f"blk{i}/exp", act="relu6")
            y = ctx.depthwise(y, f"blk{i}/dw", stride=2, act="relu6")
            y = ctx.conv2d(y, f"blk{i}/proj")
            x = y
        x = nn.avg_pool_all(x)
        return ctx.dense(x, "head")

    example = np.zeros((1, hw, hw, 3), np.float32)
    return spec, forward, example, flops


def _bert_spec(layers: int, hidden: int, seq: int, vocab: int, num_classes: int,
               num_heads: int = 4):
    """BERT-style encoder with the paper's mobile tweaks (ReLU FFN,
    affine norm). Input: int32 token ids of shape (seq,)."""
    spec: Dict[str, tuple] = {}
    spec["embed"] = (vocab, hidden)
    spec["pos"] = (seq, hidden)
    flops = 0
    for l in range(layers):
        for nm in ("q", "k", "v", "o"):
            spec[f"l{l}/att/{nm}"] = (hidden, hidden)
            spec[f"l{l}/att/{nm}/b"] = (hidden,)
        spec[f"l{l}/n1/g"] = (hidden,)
        spec[f"l{l}/n1/bb"] = (hidden,)
        spec[f"l{l}/ffn/up"] = (hidden, hidden * 4)
        spec[f"l{l}/ffn/up/b"] = (hidden * 4,)
        spec[f"l{l}/ffn/down"] = (hidden * 4, hidden)
        spec[f"l{l}/ffn/down/b"] = (hidden,)
        spec[f"l{l}/n2/g"] = (hidden,)
        spec[f"l{l}/n2/bb"] = (hidden,)
        flops += 2 * seq * hidden * hidden * 4  # qkv+o
        flops += 2 * seq * seq * hidden * 2  # attention core
        flops += 2 * seq * hidden * hidden * 4 * 2  # ffn
    spec["cls"] = (hidden, num_classes)
    spec["cls/b"] = (num_classes,)
    flops += 2 * hidden * num_classes

    def forward(ctx: Ctx, ids):
        x = ctx.embed(ids, "embed") + ctx.aux("pos")
        for l in range(layers):
            a = nn.attention(ctx, x, f"l{l}/att", num_heads)
            x = ctx.affine(x + a, f"l{l}/n1")
            f = ctx.dense(x, f"l{l}/ffn/up", act="relu")
            f = ctx.dense(f, f"l{l}/ffn/down")
            x = ctx.affine(x + f, f"l{l}/n2")
        pooled = jnp.mean(x, axis=0, keepdims=True)
        return ctx.dense(pooled, "cls")

    example = np.zeros((seq,), np.int32)
    return spec, forward, example, flops


def _yamnet_spec(num_classes: int = 521, samples: int = 15600):
    """YAMNet-lite: strided framing (96 frames x 162 samples) -> learned
    'mel' projection to 64 bands -> 2 depthwise-separable conv blocks ->
    GAP -> classifier."""
    frames, flen, mel = 96, 162, 64
    spec: Dict[str, tuple] = {
        "mel": (flen, mel),
        "mel/b": (mel,),
    }
    flops = 2 * frames * flen * mel
    c_in, h, w = 1, frames, mel
    chans = [24, 48]
    for i, c_out in enumerate(chans):
        spec[f"blk{i}/dw"] = (3, 3, c_in, 1)
        spec[f"blk{i}/dw/b"] = (c_in,)
        spec[f"blk{i}/pw"] = (1, 1, c_in, c_out)
        spec[f"blk{i}/pw/b"] = (c_out,)
        h2, w2 = h // 2, w // 2
        flops += 2 * h2 * w2 * 9 * c_in + 2 * h2 * w2 * c_in * c_out
        h, w, c_in = h2, w2, c_out
    spec["head"] = (c_in, num_classes)
    spec["head/b"] = (num_classes,)
    flops += 2 * c_in * num_classes

    def forward(ctx: Ctx, wav):
        hop = (samples - flen) // (frames - 1)
        idx = jnp.arange(frames)[:, None] * hop + jnp.arange(flen)[None, :]
        framed = wav[idx]  # (frames, flen)
        x = ctx.dense(framed, "mel", act="relu")
        x = x[None, :, :, None]  # (1, frames, mel, 1)
        for i in range(len(chans)):
            x = ctx.depthwise(x, f"blk{i}/dw", stride=2, act="relu")
            x = ctx.conv2d(x, f"blk{i}/pw", act="relu")
        x = nn.avg_pool_all(x)
        return ctx.dense(x, "head")

    example = np.zeros((samples,), np.float32)
    return spec, forward, example, flops


def _face_spec(num_out: int, batch: int = 4, hw: int = 62):
    """UC4 facial-attribute model: MNV2-style backbone, batch-4 inference
    (the face-detector upstream yields multiple crops per frame)."""
    spec, fwd_cnn, _, flops = _cnn_spec(hw=hw + 2, widths=[16, 32], num_classes=num_out)

    def forward(ctx: Ctx, x):
        x = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))  # 62 -> 64
        return fwd_cnn(ctx, x)

    example = np.zeros((batch, hw, hw, 3), np.float32)
    return spec, forward, example, flops * batch


# ---------------------------------------------------------------------------
# Executable zoo registry (python side; mirrored by rust/src/zoo).
# ---------------------------------------------------------------------------


@dataclass
class ModelDef:
    name: str
    task: str
    builder: Callable[[], tuple]
    seed: int
    schemes: Tuple[str, ...] = nn.SCHEMES
    # filled lazily
    _built: Optional[tuple] = field(default=None, repr=False)

    def build(self):
        if self._built is None:
            spec, fwd, example, flops = self.builder()
            params = nn.init_params(spec, self.seed)
            self._built = (params, fwd, example, flops)
        return self._built

    @property
    def num_params(self) -> int:
        params, _, _, _ = self.build()
        return int(sum(p.size for p in params.values()))

    @property
    def flops(self) -> int:
        return self.build()[3]

    def example_input(self) -> np.ndarray:
        return self.build()[2]

    def calibrate(self, num_batches: int = 4):
        """Run the fp32 path on random inputs recording per-layer input
        absmax (the TFLite representative-dataset step for FX8/FFX8) and
        per-parameter usage kinds (consumed by ``nn.transform_params``).

        Returns (calib, kinds).
        """
        params, fwd, example, _ = self.build()
        record: Dict[str, float] = {}
        kinds: Dict[str, str] = {}
        rng = np.random.default_rng(self.seed + 1)
        logit_max = 0.0
        for _ in range(num_batches):
            x = _random_like(example, rng)
            ctx = Ctx(params, "fp32", record=record, kinds=kinds)
            out = fwd(ctx, jnp.asarray(x))
            logit_max = max(logit_max, float(jnp.max(jnp.abs(out))))
        # FFX8 output quantisation scale: logits absmax over the
        # representative dataset (mirrors TFLite's output calibration).
        record["__logits__"] = max(logit_max, 1e-6)
        return record, kinds

    def _calib_kinds(self, calib):
        if calib is None:
            return self.calibrate()
        return calib

    def fn(self, scheme: str, calib=None):
        """Return forward(x) for the given scheme, transformed weights
        closed over as graph constants (eval path).

        Returns (run, example, in_scale).
        """
        params, fwd, example, _ = self.build()
        calib_map, kinds = self._calib_kinds(calib)
        tp = nn.transform_params(params, kinds, scheme)
        in_scale = _input_scale(example, self.seed)

        def run(x):
            ctx = Ctx(tp, scheme, calib=calib_map)
            return _wrap_io(fwd, ctx, x, scheme, example, in_scale)

        return run, example, in_scale

    def fn_params(self, scheme: str, calib=None):
        """AOT path: forward(x, *weights) with the scheme-transformed
        weights as graph *parameters* (shipped as .npz; uploaded once by
        the rust runtime as device buffers).

        Returns (run, example, weight_keys, weight_arrays, in_scale).
        """
        params, fwd, example, _ = self.build()
        calib_map, kinds = self._calib_kinds(calib)
        tp = nn.transform_params(params, kinds, scheme)
        keys = sorted(tp.keys())
        arrays = [tp[k] for k in keys]
        in_scale = _input_scale(example, self.seed)

        def run(x, *weights):
            traced = dict(zip(keys, weights))
            ctx = Ctx(traced, scheme, calib=calib_map)
            return _wrap_io(fwd, ctx, x, scheme, example, in_scale)

        return run, example, keys, arrays, in_scale


def _input_scale(example: np.ndarray, seed: int) -> float:
    scale = float(np.abs(_random_like(example, np.random.default_rng(0))).max()) / 127.0
    return max(scale, 1e-6)


def _wrap_io(fwd, ctx: Ctx, x, scheme: str, example: np.ndarray, in_scale: float):
    """Apply Table 1 I/O conventions around the forward pass."""
    if scheme == "ffx8":
        # Full-integer I/O: int8 input (int32 for token ids), int8 logits.
        if example.dtype == np.int32:
            logits = fwd(ctx, x)
        else:
            logits = fwd(ctx, x.astype(jnp.float32) * in_scale)
        # calibration-derived logit scale (TFLite output quantisation)
        ls = ctx.calib.get("__logits__", 31.75) / 127.0
        return (jnp.clip(jnp.round(logits / ls), -127, 127).astype(jnp.int8),)
    return (fwd(ctx, x),)


def _random_like(example: np.ndarray, rng) -> np.ndarray:
    if example.dtype == np.int32:
        return rng.integers(0, 1024, example.shape).astype(np.int32)
    return rng.standard_normal(example.shape).astype(np.float32)


def _int_example(example: np.ndarray) -> np.ndarray:
    return np.zeros(example.shape, np.int8)


ZOO: List[ModelDef] = [
    # UC1 — image classification (ImageNet-100 synthetic stand-in).
    ModelDef("cnn_s", "uc1", lambda: _cnn_spec(96, [16, 24, 32], 100), seed=11),
    ModelDef("cnn_m", "uc1", lambda: _cnn_spec(96, [24, 36, 48], 100), seed=12),
    ModelDef("cnn_l", "uc1", lambda: _cnn_spec(128, [32, 48, 64], 100), seed=13),
    # MobileViT stand-in: transformer-ish image model, float-only (the
    # paper's Tables 2 show no int8 variants for MobileViT).
    ModelDef("vit_xs", "uc1", lambda: _cnn_spec(128, [24, 48, 96], 100), seed=14,
             schemes=("fp32", "fp16")),
    # UC2 — text classification on Emotions (6 classes).
    ModelDef("bert_s", "uc2", lambda: _bert_spec(2, 128, 64, 1024, 6), seed=21),
    ModelDef("bert_m", "uc2", lambda: _bert_spec(4, 192, 64, 1024, 6), seed=22),
    ModelDef("bert_l", "uc2", lambda: _bert_spec(6, 256, 64, 1024, 6), seed=23),
    # UC3 — scene classification (67 classes) + audio (521 classes).
    ModelDef("scene_s", "uc3", lambda: _cnn_spec(96, [16, 24, 32], 67), seed=31),
    ModelDef("scene_m", "uc3", lambda: _cnn_spec(112, [24, 36, 48], 67), seed=32),
    ModelDef("scene_l", "uc3", lambda: _cnn_spec(128, [32, 48, 64], 67), seed=33),
    ModelDef("yamnet_lite", "uc3", lambda: _yamnet_spec(), seed=34,
             schemes=("fp32", "fp16", "dr8")),  # Table 4: YAMNet has no FX8/FFX8
    # UC4 — facial attributes, batch 4.
    ModelDef("face_gender", "uc4", lambda: _face_spec(2), seed=41),
    ModelDef("face_age", "uc4", lambda: _face_spec(1), seed=42),
    ModelDef("face_eth", "uc4", lambda: _face_spec(5), seed=43),
]


def get(name: str) -> ModelDef:
    for m in ZOO:
        if m.name == name:
            return m
    raise KeyError(name)
