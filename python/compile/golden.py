"""Golden outputs for cross-language validation.

For every artifact in the manifest, runs the jitted model on an all-zeros
input and records the first 8 output values. The rust test-suite
(`rust/tests/runtime_pjrt.rs`) replays the same zero input through the
PJRT engine and asserts the numbers match — proving the HLO-text + npz
interchange preserves semantics end to end.

Usage: python -m compile.golden --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import numpy as np

from . import model as M


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()

    with open(os.path.join(args.out_dir, "manifest.json")) as f:
        manifest = json.load(f)

    goldens = {}
    calib_cache = {}
    for e in manifest:
        md = M.get(e["model"])
        if e["model"] not in calib_cache:
            calib_cache[e["model"]] = md.calibrate()
        run, _, keys, arrays, _ = md.fn_params(e["scheme"], calib=calib_cache[e["model"]])
        assert keys == e["weight_keys"], f"key order drift for {e['file']}"
        dtype = {"float32": np.float32, "int32": np.int32, "int8": np.int8}[
            e["input"]["dtype"]
        ]
        x = np.zeros(e["input"]["shape"], dtype)
        out = np.asarray(jax.jit(run)(x, *arrays)[0]).reshape(-1)
        stem = e["file"].replace(".hlo.txt", "")
        goldens[stem] = [float(v) for v in out[:8]]
        print(f"[golden] {stem:28s} {goldens[stem][:4]}", flush=True)

    with open(os.path.join(args.out_dir, "goldens.json"), "w") as f:
        json.dump(goldens, f, indent=1)
    print(f"[golden] wrote {len(goldens)} entries")


if __name__ == "__main__":
    main()
