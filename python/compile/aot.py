"""AOT compile path: lower every (model, scheme) pair in the executable
zoo to HLO **text** + a weights ``.npz`` + ``artifacts/manifest.json``.

Interchange format notes (see /opt/xla-example/README.md):

* HLO text, not ``.serialize()`` — jax >= 0.5 emits HloModuleProtos with
  64-bit instruction ids which the rust side's xla_extension 0.5.1
  rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids.
* Weights are graph *parameters*, not baked constants — the HLO text
  printer elides large constants (``constant({...})``), and multi-MB
  decimal-printed tensors would bloat artifacts and parse time anyway.
  The transformed (scheme-specific) weight tensors are saved to an
  ``.npz`` whose key order is recorded in the manifest; the rust runtime
  uploads them once as PJRT device buffers and passes them after the
  input on every execute call.

Run once via ``make artifacts``; python never executes on the request
path.

Usage:
    python -m compile.aot --out-dir ../artifacts [--models cnn_s,bert_s]
                          [--schemes fp32,ffx8] [--check]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from . import nn


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def example_for(md: M.ModelDef, scheme: str) -> np.ndarray:
    ex = md.example_input()
    if scheme == "ffx8" and ex.dtype != np.int32:
        return np.zeros(ex.shape, np.int8)
    return ex


def random_input(ex: np.ndarray, seed: int = 7) -> np.ndarray:
    rng = np.random.default_rng(seed)
    if ex.dtype == np.int32:
        return rng.integers(0, 1024, ex.shape).astype(np.int32)
    if ex.dtype == np.int8:
        return rng.integers(-100, 100, ex.shape).astype(np.int8)
    return rng.standard_normal(ex.shape).astype(np.float32)


def export_one(md: M.ModelDef, scheme: str, out_dir: str, calib, check: bool):
    run, example, keys, arrays, in_scale = md.fn_params(scheme, calib=calib)
    ex = example_for(md, scheme)
    specs = [jax.ShapeDtypeStruct(ex.shape, ex.dtype)] + [
        jax.ShapeDtypeStruct(a.shape, a.dtype) for a in arrays
    ]
    lowered = jax.jit(run).lower(*specs)
    text = to_hlo_text(lowered)
    stem = f"{md.name}_{scheme}"
    with open(os.path.join(out_dir, stem + ".hlo.txt"), "w") as f:
        f.write(text)
    # npz with sorted keys == parameter order after the input.
    np.savez(os.path.join(out_dir, stem + ".npz"), **dict(zip(keys, arrays)))

    out_shapes = [
        {"shape": list(o.shape), "dtype": str(o.dtype)}
        for o in jax.eval_shape(run, *specs)
    ]
    if check:
        x = random_input(ex)
        got = jax.jit(run)(x, *arrays)
        ref = run(jnp.asarray(x), *[jnp.asarray(a) for a in arrays])
        # dr8's dynamic activation scales are absmax reductions whose
        # jit/eager evaluation order may differ by 1 ulp, which perturbs
        # the int8 rounding; allow a quantisation-step-sized tolerance on
        # the integer schemes.
        atol, rtol = (2e-2, 5e-2) if scheme in nn.INT8_SCHEMES else (2e-4, 1e-3)
        for g, r in zip(got, ref):
            np.testing.assert_allclose(
                np.asarray(g).astype(np.float32),
                np.asarray(r).astype(np.float32),
                atol=atol, rtol=rtol,
            )

    weight_bytes = int(sum(a.nbytes for a in arrays))
    return {
        "file": stem + ".hlo.txt",
        "weights": stem + ".npz",
        "weight_keys": keys,
        "model": md.name,
        "task": md.task,
        "scheme": scheme,
        "input": {"shape": list(ex.shape), "dtype": str(ex.dtype)},
        "outputs": out_shapes,
        "params": md.num_params,
        "flops": md.flops,
        "weight_bytes": weight_bytes,
        "input_scale": in_scale if scheme == "ffx8" else None,
        "hlo_bytes": len(text),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default="")
    ap.add_argument("--schemes", default="")
    ap.add_argument("--check", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    want_models = set(filter(None, args.models.split(",")))
    want_schemes = set(filter(None, args.schemes.split(",")))

    manifest = []
    for md in M.ZOO:
        if want_models and md.name not in want_models:
            continue
        calib = md.calibrate()
        for scheme in md.schemes:
            if want_schemes and scheme not in want_schemes:
                continue
            t0 = time.time()
            entry = export_one(md, scheme, args.out_dir, calib, args.check)
            manifest.append(entry)
            print(
                f"[aot] {entry['file']:28s} params={entry['params']:>8d} "
                f"flops={entry['flops']:>12d} hlo={entry['hlo_bytes']:>9d}B "
                f"({time.time() - t0:.1f}s)",
                flush=True,
            )

    man_path = os.path.join(args.out_dir, "manifest.json")
    existing = []
    if (want_models or want_schemes) and os.path.exists(man_path):
        with open(man_path) as f:
            existing = [
                e for e in json.load(f)
                if not any(e["file"] == n["file"] for n in manifest)
            ]
    with open(man_path, "w") as f:
        json.dump(existing + manifest, f, indent=1)
    print(f"[aot] wrote {len(manifest)} artifacts + manifest to {args.out_dir}")


if __name__ == "__main__":
    main()
