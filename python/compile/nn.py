"""L2 neural-network primitives, built on the L1 Pallas kernels.

Every matmul-shaped computation (dense layers and im2col-lowered
convolutions) goes through ``kernels.qmatmul`` so that the Pallas kernel
is the single compute hot-spot of the whole zoo. Depthwise convolutions
are executed as grouped ``lax.conv`` in f32 (they are <3% of the FLOPs of
any zoo model; TFLite quantises them too, a divergence documented in
DESIGN.md §6).

Weight handling mirrors the TFLite converter: a *transform* step turns the
raw f32 training parameters into the scheme-specific tensor set (Table 1 of
the paper) — f16 casts for FP16, symmetric per-channel int8 + scales for
DR8/FX8/FFX8. The transformed tensors are either baked into the graph as
constants (eval path) or exposed as graph *parameters* and shipped as an
``.npz`` next to the HLO (AOT path; the rust runtime uploads them once as
device buffers — python never runs at serving time).

``Ctx`` dispatches each layer according to the quantisation scheme and
doubles as the calibration recorder for the static-range schemes
(FX8/FFX8).
"""

from __future__ import annotations

import math
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import kernels as K

SCHEMES = ("fp32", "fp16", "dr8", "fx8", "ffx8")
INT8_SCHEMES = ("dr8", "fx8", "ffx8")

# Weight bytes per parameter for each scheme (Table 1: fp16 halves, the
# int8 schemes quarter the model size).
BYTES_PER_PARAM = {"fp32": 4.0, "fp16": 2.0, "dr8": 1.0, "fx8": 1.0, "ffx8": 1.0}


def init_params(spec, seed: int) -> Dict[str, np.ndarray]:
    """Deterministic He-style init for a dict of {name: shape}."""
    rng = np.random.default_rng(seed)
    params = {}
    for name, shape in spec.items():
        if name.endswith("/b"):
            params[name] = np.zeros(shape, np.float32)
        else:
            fan_in = int(np.prod(shape[:-1])) if len(shape) > 1 else shape[0]
            std = math.sqrt(2.0 / max(fan_in, 1))
            params[name] = rng.standard_normal(shape).astype(np.float32) * std
    return params


def np_quantize_weights(w: np.ndarray):
    """Symmetric per-output-channel int8 quantisation (numpy, convert-time).

    w is 2D (K, N); returns (w_q int8 (K, N), scale f32 (N,)).
    """
    amax = np.max(np.abs(w), axis=0)
    scale = np.maximum(amax, 1e-8) / 127.0
    w_q = np.clip(np.round(w / scale[None, :]), -127, 127).astype(np.int8)
    return w_q, scale.astype(np.float32)


def transform_params(
    params: Dict[str, np.ndarray], kinds: Dict[str, str], scheme: str
) -> Dict[str, np.ndarray]:
    """TFLite-converter step: raw f32 params -> scheme-specific tensor set.

    kinds maps each non-bias parameter to its usage recorded during the
    calibration pass: 'dense' (matmul weight), 'dw' (depthwise filter),
    'embed' (lookup table) or 'aux' (affine/positional, stays float).
    """
    assert scheme in SCHEMES, scheme
    tp: Dict[str, np.ndarray] = {}
    for name, w in params.items():
        kind = "bias" if name.endswith("/b") else kinds.get(name, "aux")
        if kind == "dense":
            w2 = w.reshape(-1, w.shape[-1]).astype(np.float32)
            if scheme in INT8_SCHEMES:
                tp[name + "!q"], tp[name + "!s"] = np_quantize_weights(w2)
            elif scheme == "fp16":
                tp[name] = w2.astype(np.float16)
            else:
                tp[name] = w2
        elif kind == "dw":
            # (kh, kw, c, 1) -> HWIO (kh, kw, 1, c); float path always.
            w4 = np.transpose(w, (0, 1, 3, 2)).astype(np.float32)
            tp[name] = w4.astype(np.float16) if scheme == "fp16" else w4
        elif kind == "embed":
            if scheme in INT8_SCHEMES:
                amax = max(float(np.max(np.abs(w))), 1e-8)
                scale = amax / 127.0
                tp[name + "!q"] = np.clip(np.round(w / scale), -127, 127).astype(np.int8)
                tp[name + "!s"] = np.array([scale], np.float32)
            elif scheme == "fp16":
                tp[name] = w.astype(np.float16)
            else:
                tp[name] = w.astype(np.float32)
        else:  # bias / aux — always f32
            tp[name] = w.astype(np.float32)
    return tp


class Ctx:
    """Scheme-dispatching layer context.

    tp:     transformed parameter dict (np arrays for the baked path, or
            traced jax arrays for the AOT-parameterised path). In record
            mode this is the *raw* f32 param dict instead.
    calib:  {dense layer name: activation absmax} from a calibration pass,
            used by fx8/ffx8 static quantisation.
    record: when not None, runs the fp32 path recording each dense layer's
            input absmax into ``record`` and parameter usage kinds into
            ``kinds`` (calibration mode).
    """

    def __init__(
        self,
        tp: Dict[str, np.ndarray],
        scheme: str,
        calib: Optional[Dict[str, float]] = None,
        record: Optional[Dict[str, float]] = None,
        kinds: Optional[Dict[str, str]] = None,
    ):
        assert scheme in SCHEMES, scheme
        self.tp = tp
        self.recording = record is not None
        self.scheme = "fp32" if self.recording else scheme
        self.calib = calib or {}
        self.record = record
        self.kinds = kinds if kinds is not None else {}

    # -- parameter access ---------------------------------------------------

    def _get(self, name: str):
        v = self.tp[name]
        v = jnp.asarray(v)
        if v.dtype == jnp.float16:
            # FP16 scheme: weights stored half precision, dequantised to f32
            # before first use (Table 1 CPU-fallback path).
            v = v.astype(jnp.float32)
        return v

    def _b(self, name: str):
        key = name + "/b"
        return self._get(key) if key in self.tp else None

    def aux(self, name: str):
        """Float auxiliary parameter (positional embeddings etc.)."""
        if self.recording:
            self.kinds.setdefault(name, "aux")
        return self._get(name)

    # -- layers ---------------------------------------------------------------

    def dense(self, x, name: str, act: Optional[str] = None):
        """(M, K) @ W (K, N) + bias, through the Pallas kernel."""
        if self.recording:
            self.kinds[name] = "dense"
            self.record[name] = max(
                self.record.get(name, 0.0), float(jnp.max(jnp.abs(x)))
            )
            w = jnp.asarray(self.tp[name].reshape(-1, self.tp[name].shape[-1]))
            out = K.dense_f32(x, w, self._b(name))
        elif self.scheme in ("fp32", "fp16"):
            out = K.dense_f32(x, self._get(name), self._b(name))
        elif self.scheme == "dr8":
            out = K.dense_dr8(x, self._get(name + "!q"), self._get(name + "!s"),
                              self._b(name))
        else:  # fx8 / ffx8
            x_scale = self.calib.get(name, 1.0) / 127.0
            out = K.dense_fx8(x, self._get(name + "!q"), self._get(name + "!s"),
                              x_scale, self._b(name))
        return _activate(out, act)

    def conv2d(self, x, name: str, stride: int = 1, act: Optional[str] = None):
        """NHWC conv via im2col + the dense path (same quant dispatch).

        The raw parameter has shape (kh, kw, cin, cout); transform flattens
        it to (kh*kw*cin, cout), matching the patch feature order below.
        """
        n, h, w_, cin = x.shape
        if self.recording:
            kh, kw, _, cout = self.tp[name].shape
        else:
            key = name + "!q" if self.scheme in INT8_SCHEMES else name
            kdim, cout = self.tp[key].shape
            kk = kdim // cin
            kh = kw = int(math.isqrt(kk))
        pad = ((kh - 1) // 2, kh // 2), ((kw - 1) // 2, kw // 2)
        patches = jax.lax.conv_general_dilated_patches(
            x,
            filter_shape=(kh, kw),
            window_strides=(stride, stride),
            padding=pad,
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
        oh, ow = patches.shape[1], patches.shape[2]
        # conv_general_dilated_patches yields channel-major (C, kh, kw)
        # feature order; permute to (kh, kw, C) to match HWIO weights.
        patches = patches.reshape(n, oh, ow, cin, kh * kw)
        patches = jnp.moveaxis(patches, 3, 4).reshape(n * oh * ow, kh * kw * cin)
        out = self.dense(patches, name, act=None)
        out = out.reshape(n, oh, ow, cout)
        return _activate(out, act)

    def depthwise(self, x, name: str, stride: int = 1, act: Optional[str] = None):
        """Depthwise 3x3 conv, f32 path (grouped lax.conv)."""
        if self.recording:
            self.kinds[name] = "dw"
            wdw = jnp.transpose(jnp.asarray(self.tp[name]), (0, 1, 3, 2))
        else:
            wdw = self._get(name)  # already HWIO from transform
        kh = wdw.shape[0]
        out = jax.lax.conv_general_dilated(
            x.astype(jnp.float32),
            wdw,
            window_strides=(stride, stride),
            padding=[((kh - 1) // 2, kh // 2)] * 2,
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=x.shape[-1],
        )
        b = self._b(name)
        if b is not None:
            out = out + b
        return _activate(out, act)

    def embed(self, ids, name: str):
        """Token embedding lookup; int8 table for the quantised schemes."""
        if self.recording:
            self.kinds[name] = "embed"
            return jnp.take(jnp.asarray(self.tp[name]), ids, axis=0)
        if self.scheme in INT8_SCHEMES:
            t_q = self._get(name + "!q")
            scale = self._get(name + "!s")[0]
            return jnp.take(t_q, ids, axis=0).astype(jnp.float32) * scale
        return jnp.take(self._get(name), ids, axis=0)

    def affine(self, x, name: str):
        """Folded batch-norm (inference-time affine): x * g + b."""
        if self.recording:
            self.kinds.setdefault(name + "/g", "aux")
            self.kinds.setdefault(name + "/bb", "aux")
        return x * self._get(name + "/g") + self._get(name + "/bb")


def _activate(x, act: Optional[str]):
    if act is None:
        return x
    if act == "relu":
        return jax.nn.relu(x)
    if act == "relu6":
        return jnp.clip(x, 0.0, 6.0)
    if act == "gelu":
        return jax.nn.gelu(x)
    if act == "tanh":
        return jnp.tanh(x)
    raise ValueError(act)


def attention(ctx: Ctx, x, prefix: str, num_heads: int):
    """Multi-head self-attention block; QKV/out projections go through the
    Pallas dense path, the softmax core stays f32 (as in TFLite)."""
    s, h = x.shape
    dh = h // num_heads
    q = ctx.dense(x, f"{prefix}/q").reshape(s, num_heads, dh)
    k = ctx.dense(x, f"{prefix}/k").reshape(s, num_heads, dh)
    v = ctx.dense(x, f"{prefix}/v").reshape(s, num_heads, dh)
    att = jnp.einsum("qhd,khd->hqk", q, k) / math.sqrt(dh)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("hqk,khd->qhd", att, v).reshape(s, h)
    return ctx.dense(out, f"{prefix}/o")


def avg_pool_all(x):
    """Global average pool NHWC -> NC."""
    return jnp.mean(x, axis=(1, 2))
